"""Telemetry subsystem (DESIGN.md §8): in-jit stats, sink, controllers,
state migration, and the closed adaptive loop end-to-end."""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dct import dct2_matrix
from repro.core.selection import column_norms, index_overlap, topr_margin
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim.api import get_optimizer
from repro.optim.common import Context
from repro.optim.projected_adam import ProjAdamLeaf, ProjectedAdamRule
from repro.telemetry.adaptive import AdaptiveOptimizerManager
from repro.telemetry.controllers import (
    LeafInfo,
    RankAllocator,
    RankAllocatorConfig,
    RefreshScheduler,
    RefreshSchedulerConfig,
    leaf_inventory,
    merge_overrides,
    migrate_opt_state,
)
from repro.telemetry.sink import TelemetrySink, flatten_record
from repro.telemetry.stats import SubspaceStats, collect, summarize
from repro.train.loop import Trainer
from repro.train.steps import init_state, make_train_step


def _tiny():
    return ModelConfig(
        name="tiny", family="dense", d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=128, schedule=((("attn",), 2),),
        param_dtype="float32", compute_dtype="float32", remat=False,
        q_chunk=32, kv_chunk=32)


def _leaf_update(rule, shape, steps=1, seed=0):
    """Drive rule.update under a collector; return per-step stats."""
    rng = np.random.default_rng(seed)
    state = rule.init(shape, jnp.float32)
    param = jnp.zeros(shape, jnp.float32)
    q = dct2_matrix(shape[-1] if shape[-1] <= shape[-2] else shape[-2])
    bases = {str(q.shape[-1]): q}
    out = []

    def step_fn(g, state, step):
        with collect() as col:
            ctx = Context(step=step, bases=bases,
                          key=jax.random.PRNGKey(7), stats=col.scope("w"))
            d, ns = rule.update(g, state, param, ctx)
        return d, ns, col.tree()

    jf = jax.jit(step_fn)
    for t in range(1, steps + 1):
        g = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        d, state, tel = jf(g, state, jnp.asarray(t, jnp.int32))
        out.append(tel["w"])
    return out, state


# ---------------------------------------------------------------------------
# in-jit stats
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fused", ["off", "fft", "on"])
def test_stats_agree_across_execution_layers(fused):
    """captured_energy / overlap / ef_norm identical across the reference,
    Makhoul-fft and Pallas-kernel execution layers."""
    rule = ProjectedAdamRule(rank=8, projector="dct", residual="ef",
                             ef_dtype="q8", fused="off")
    (ref,), _ = _leaf_update(rule, (3, 24, 40))
    (got,), _ = _leaf_update(dataclasses.replace(rule, fused=fused),
                             (3, 24, 40))
    np.testing.assert_allclose(np.asarray(got.captured_energy),
                               np.asarray(ref.captured_energy),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got.index_overlap),
                                  np.asarray(ref.index_overlap))
    np.testing.assert_allclose(np.asarray(got.ef_norm),
                               np.asarray(ref.ef_norm), rtol=1e-3)
    np.testing.assert_allclose(np.asarray(got.rank_utilization),
                               np.asarray(ref.rank_utilization), rtol=1e-4)


def test_stats_keep_step_sentinels():
    """T_u > 1: keep steps report the -1 not-a-measurement sentinel for
    both margin and overlap; refresh steps report real values (fused path
    keeps norms resident)."""
    rule = ProjectedAdamRule(rank=8, projector="dct", residual="ef",
                             ef_dtype="q8", update_interval=3, fused="fft")
    stats, _ = _leaf_update(rule, (24, 40), steps=4)
    assert float(stats[0].topr_margin) >= 0          # step 1: refresh
    assert float(stats[0].index_overlap) >= 0
    for t in (1, 2):                                  # steps 2-3: keep
        assert float(stats[t].topr_margin) == -1.0
        assert float(stats[t].index_overlap) == -1.0
    assert float(stats[3].topr_margin) >= 0          # step 4: refresh
    assert float(stats[3].index_overlap) >= 0


def test_stats_ef_norm_matches_buffer():
    """ef_norm equals the Frobenius norm of the stored residual."""
    rule = ProjectedAdamRule(rank=8, projector="dct", residual="ef",
                             ef_dtype="fp32", fused="off")
    (st,), state = _leaf_update(rule, (24, 40))
    np.testing.assert_allclose(
        float(st.ef_norm), float(jnp.linalg.norm(state.ef)), rtol=1e-5)


def test_no_collector_no_graph_change():
    """With no collector the lowered HLO is identical to the seed graph —
    telemetry off costs exactly nothing."""
    opt = get_optimizer("dct_adamw", lr=1e-3, rank=8, fused="fft")
    params = {"w": jnp.zeros((24, 40), jnp.float32)}
    grads = {"w": jnp.ones((24, 40), jnp.float32)}
    state = opt.init(params)

    def lower():
        return jax.jit(opt.update).lower(grads, state, params).as_text()

    base = lower()
    with collect() as col:
        # collector active but update NOT traced inside it -> same graph
        pass
    assert lower() == base
    assert col.tree() == {}


def test_emit_stats_optout():
    rule = ProjectedAdamRule(rank=8, projector="dct", residual="ef",
                             ef_dtype="q8", fused="fft", emit_stats=False)
    with collect() as col:
        state = rule.init((24, 40), jnp.float32)
        ctx = Context(step=jnp.int32(1), bases={"40": dct2_matrix(40)},
                      stats=col.scope("w"))
        rule.update(jnp.ones((24, 40)), state, jnp.zeros((24, 40)), ctx)
    assert col.tree() == {}


def test_train_step_metrics_carry_telemetry():
    cfg = _tiny()
    opt = get_optimizer("dct_adamw", lr=1e-3, rank=8, fused="fft")
    step_fn = jax.jit(make_train_step(cfg, opt, telemetry=True))
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "targets": jnp.zeros((2, 16), jnp.int32)}
    state, metrics = step_fn(state, batch)
    tel = metrics["telemetry"]
    assert tel, "no SubspaceStats emitted through the train step"
    for st in tel.values():
        assert isinstance(st, SubspaceStats)
        ce = np.asarray(st.captured_energy)
        assert np.all((ce >= 0) & (ce <= 1 + 1e-5))


# ---------------------------------------------------------------------------
# selection helpers
# ---------------------------------------------------------------------------
def test_index_overlap_helper():
    a = jnp.asarray([[0, 1, 2, 3], [4, 5, 6, 7]], jnp.int32)
    b = jnp.asarray([[2, 3, 8, 9], [4, 5, 6, 7]], jnp.int32)
    np.testing.assert_allclose(np.asarray(index_overlap(a, b)), [0.5, 1.0])


def test_topr_margin_helper():
    norms = jnp.asarray([10.0, 8.0, 4.0, 1.0])
    # r=2: (8-4)/10
    np.testing.assert_allclose(float(topr_margin(norms, 2)), 0.4, rtol=1e-6)
    assert float(topr_margin(norms, 4)) == 1.0       # nothing dropped


# ---------------------------------------------------------------------------
# sink
# ---------------------------------------------------------------------------
def _record(step, loss, ce):
    return {"step": step, "s_per_step": 0.01, "loss": jnp.float32(loss),
            "telemetry": {"w": SubspaceStats(
                captured_energy=jnp.asarray([ce, ce + 0.1]),
                topr_margin=jnp.float32(0.2),
                index_overlap=jnp.float32(0.9),
                ef_norm=jnp.float32(1.0),
                rank_utilization=jnp.float32(0.8))}}


def test_sink_jsonl_bucketing(tmp_path):
    path = str(tmp_path / "tel.jsonl")
    with TelemetrySink(path, fmt="jsonl", every=2, ring=8) as sink:
        for s in range(1, 5):
            sink.log_metrics(_record(s, loss=float(s), ce=0.5))
    rows = [json.loads(l) for l in open(path)]
    assert len(rows) == 2                            # 4 steps / every=2
    assert rows[0]["step"] == 2 and rows[1]["step"] == 4
    assert rows[0]["loss"] == pytest.approx(1.5)     # mean of steps 1-2
    # stacked stats stay elementwise lists in jsonl
    assert rows[0]["telemetry/w/captured_energy"] == pytest.approx([0.5, 0.6])
    assert sink.history() == rows


def test_sink_partial_bucket_flush(tmp_path):
    path = str(tmp_path / "tel.jsonl")
    sink = TelemetrySink(path, fmt="jsonl", every=10)
    for s in range(1, 4):
        sink.log_metrics(_record(s, loss=1.0, ce=0.5))
    sink.close()                                     # flushes the partial
    rows = [json.loads(l) for l in open(path)]
    assert len(rows) == 1 and rows[0]["step"] == 3


def test_sink_csv(tmp_path):
    path = str(tmp_path / "tel.csv")
    with TelemetrySink(path, fmt="csv", every=2) as sink:
        for s in range(1, 5):
            sink.log_metrics(_record(s, loss=2.0, ce=0.4))
    lines = open(path).read().strip().splitlines()
    assert len(lines) == 3                           # header + 2 rows
    header = lines[0].split(",")
    assert "loss" in header
    assert "telemetry/w/captured_energy" in header
    row = dict(zip(header, lines[1].split(",")))
    # CSV collapses stacked lists to their mean
    assert float(row["telemetry/w/captured_energy"]) == pytest.approx(0.45)


def test_flatten_record_paths():
    flat = flatten_record(_record(7, loss=3.0, ce=0.2))
    assert flat["step"] == 7.0
    assert flat["telemetry/w/ef_norm"] == 1.0


def test_sink_sentinel_aware_aggregation(tmp_path):
    """-1 not-a-measurement sentinels (keep steps) must not be averaged
    into real margin/overlap measurements; all-sentinel buckets stay -1."""
    def rec(step, margin, overlap):
        return {"step": step, "s_per_step": 0.01,
                "telemetry": {"w": SubspaceStats(
                    captured_energy=jnp.float32(0.5),
                    topr_margin=jnp.float32(margin),
                    index_overlap=jnp.float32(overlap),
                    ef_norm=jnp.float32(1.0),
                    rank_utilization=jnp.float32(0.8))}}

    path = str(tmp_path / "tel.jsonl")
    with TelemetrySink(path, fmt="jsonl", every=4) as sink:
        # refresh at step 1 (real values), keep at 2-4 (sentinels)
        sink.log_metrics(rec(1, margin=0.4, overlap=0.8))
        for s in (2, 3, 4):
            sink.log_metrics(rec(s, margin=-1.0, overlap=-1.0))
        # second bucket: keep steps only
        for s in (5, 6, 7, 8):
            sink.log_metrics(rec(s, margin=-1.0, overlap=-1.0))
    rows = [json.loads(l) for l in open(path)]
    assert rows[0]["telemetry/w/topr_margin"] == pytest.approx(0.4)
    assert rows[0]["telemetry/w/index_overlap"] == pytest.approx(0.8)
    assert rows[1]["telemetry/w/topr_margin"] == -1.0
    assert rows[1]["telemetry/w/index_overlap"] == -1.0
    # non-sentinel fields keep the plain mean
    assert rows[0]["telemetry/w/captured_energy"] == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# controllers
# ---------------------------------------------------------------------------
def _three_leaves():
    return {"a": LeafInfo(rows=64, cols=64),
            "b": LeafInfo(rows=64, cols=64),
            "c": LeafInfo(rows=64, cols=64)}


def _alloc_cfg(**kw):
    kw.setdefault("base_rank", 32)
    kw.setdefault("decide_every", 1)
    kw.setdefault("deadband", 0.0)
    return RankAllocatorConfig(**kw)


def test_rank_allocator_moves_rank_toward_deficit():
    alloc = RankAllocator(_alloc_cfg(), _three_leaves())
    stats = {"a": {"captured_energy": 0.95},      # over-provisioned
             "b": {"captured_energy": 0.50},
             "c": {"captured_energy": 0.10}}      # starved
    for step in range(1, 12):
        for _ in range(8):
            alloc.observe(step, stats)
        alloc.propose(step)
    assert alloc.alloc["c"] > alloc.alloc["b"] > alloc.alloc["a"]
    # budget (weighted rank units) conserved
    used = sum(li.rows * alloc.alloc[p]
               for p, li in alloc.leaves.items())
    assert used <= alloc.budget
    # bounds respected
    for p, r in alloc.alloc.items():
        assert _alloc_cfg().floor() <= r <= _alloc_cfg().cap()
        assert r % _alloc_cfg().quantum == 0


def test_rank_allocator_hysteresis_and_deadband():
    alloc = RankAllocator(_alloc_cfg(deadband=0.05), _three_leaves())
    flat = {p: {"captured_energy": 0.5} for p in "abc"}
    for _ in range(8):
        alloc.observe(1, flat)
    assert alloc.propose(1) is None                  # spread < deadband
    # per-decision move is rate-limited to max_step quanta
    cfg = _alloc_cfg(max_step=1)
    alloc2 = RankAllocator(cfg, _three_leaves())
    stats = {"a": {"captured_energy": 0.99},
             "b": {"captured_energy": 0.5},
             "c": {"captured_energy": 0.01}}
    for _ in range(50):
        alloc2.observe(1, stats)
    alloc2.propose(1)
    assert abs(alloc2.alloc["c"] - 32) <= cfg.max_step * cfg.quantum
    # decide_every gating
    alloc3 = RankAllocator(_alloc_cfg(decide_every=100), _three_leaves())
    for _ in range(8):
        alloc3.observe(5, stats)
    assert alloc3.propose(5) is None                 # too soon


def test_rank_allocator_respects_cols_cap():
    leaves = {"small": LeafInfo(rows=512, cols=16),
              "big": LeafInfo(rows=512, cols=512)}
    alloc = RankAllocator(_alloc_cfg(), leaves)
    assert alloc.alloc["small"] == 16                # rank can't exceed cols
    stats = {"small": {"captured_energy": 0.05},
             "big": {"captured_energy": 0.9}}
    for step in range(1, 6):
        for _ in range(8):
            alloc.observe(step, stats)
        alloc.propose(step)
    assert alloc.alloc["small"] <= 16


def test_refresh_scheduler_ladder():
    cfg = RefreshSchedulerConfig(base_interval=1, decide_every=1, cooldown=0)
    sched = RefreshScheduler(cfg, ["w"])
    calm = {"w": {"captured_energy": 0.5, "topr_margin": 0.3,
                  "index_overlap": 0.95}}
    for step in range(1, 5):
        for _ in range(10):
            sched.observe(step, calm)
        sched.propose(step)
    assert sched.interval["w"] > 1                   # stretched
    stretched = sched.interval["w"]
    stormy = {"w": {"captured_energy": 0.5, "topr_margin": 0.3,
                    "index_overlap": 0.1}}
    for step in range(5, 12):
        for _ in range(10):
            sched.observe(step, stormy)
        sched.propose(step)
    assert sched.interval["w"] < stretched           # shrank back
    # the -1 not-a-measurement sentinel (keep steps, basis projectors) is
    # ignored; a genuine drift-0 refresh observation (overlap 1.0) is not
    sched2 = RefreshScheduler(cfg, ["w"])
    sched2.observe(1, {"w": {"captured_energy": 0.5, "topr_margin": -1.0,
                             "index_overlap": -1.0}})
    assert sched2.drift_ema == {}
    sched2.observe(1, {"w": {"captured_energy": 0.5, "topr_margin": -1.0,
                             "index_overlap": 1.0}})
    assert sched2.drift_ema["w"] == 0.0


def test_merge_overrides():
    m = merge_overrides({"a": {"rank": 16}},
                        {"a": {"update_interval": 4}, "b": {"rank": 8}},
                        None)
    assert m == {"a": {"rank": 16, "update_interval": 4}, "b": {"rank": 8}}


# ---------------------------------------------------------------------------
# state migration
# ---------------------------------------------------------------------------
def test_migrate_opt_state_preserves_what_survives():
    params = {"w": jnp.zeros((48, 32), jnp.float32),
              "u": jnp.zeros((48, 32), jnp.float32),
              "norm_scale": jnp.zeros((8,), jnp.float32)}
    grads = jax.tree.map(
        lambda p: jax.random.normal(jax.random.PRNGKey(1), p.shape), params)
    opt_old = get_optimizer("dct_adamw", lr=1e-3, rank=8, ef_dtype="fp32",
                            fused="fft")
    state = opt_old.init(params)
    for _ in range(3):                               # build up moments + EF
        _, state = jax.jit(opt_old.update)(grads, state, params)

    opt_new = get_optimizer("dct_adamw", lr=1e-3, rank=8, ef_dtype="fp32",
                            fused="fft", overrides={"w": {"rank": 16}})
    migrated = migrate_opt_state(state, opt_new.init(params))

    def leaf_states(s):
        out = {}

        def visit(kp, leaf):
            if isinstance(leaf, ProjAdamLeaf):
                segs = [str(getattr(k, "key", k)) for k in kp]
                out[[p for p in ("w", "u") if p in segs][0]] = leaf
            return leaf
        jax.tree_util.tree_map_with_path(
            visit, s, is_leaf=lambda x: isinstance(x, ProjAdamLeaf))
        return out

    old_l, new_l = leaf_states(state), leaf_states(migrated)
    # chain bookkeeping survives
    assert int(migrated.step) == int(state.step) == 3
    # unchanged leaf: moments carried over verbatim
    np.testing.assert_array_equal(np.asarray(new_l["u"].m),
                                  np.asarray(old_l["u"].m))
    assert int(new_l["u"].inner_step) == 3
    # changed leaf: rank-r buffers reset, inner bias-correction clock too
    assert new_l["w"].m.shape[-1] == 16
    assert float(jnp.abs(new_l["w"].m).sum()) == 0.0
    assert int(new_l["w"].inner_step) == 0
    # ...but the rank-independent EF buffer carries the residual history
    np.testing.assert_array_equal(np.asarray(new_l["w"].ef),
                                  np.asarray(old_l["w"].ef))
    assert float(jnp.abs(new_l["w"].ef).sum()) > 0

    # migrated state is usable: one more step under the new optimizer
    _, state2 = jax.jit(opt_new.update)(grads, migrated, params)
    assert int(state2.step) == 4


# ---------------------------------------------------------------------------
# closed loop end-to-end
# ---------------------------------------------------------------------------
def test_adaptive_loop_reallocates_and_trains(tmp_path):
    """Full closed loop on a tiny model: telemetry -> allocator decision ->
    optimizer rebuild + state migration -> training continues. Aggressive
    config (deadband 0, decide every 2) forces at least one rebuild."""
    cfg = _tiny()

    def make_optimizer(overrides=None):
        return get_optimizer("dct_adamw", lr=1e-3, rank=8, fused="fft",
                             overrides=overrides)

    def make_step(opt):
        return jax.jit(make_train_step(cfg, opt, telemetry=True))

    params_sds = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    leaves = leaf_inventory(params_sds)
    allocator = RankAllocator(
        RankAllocatorConfig(base_rank=8, quantum=2, max_step=2,
                            decide_every=2, deadband=0.0, ema_decay=0.5),
        leaves)
    scheduler = RefreshScheduler(
        RefreshSchedulerConfig(decide_every=2, cooldown=2, low_drift=0.99,
                               max_interval=4),
        leaves)
    manager = AdaptiveOptimizerManager(
        make_optimizer=make_optimizer, make_step=make_step,
        make_train_state=lambda opt: init_state(cfg, opt,
                                                jax.random.PRNGKey(0)),
        rank_allocator=allocator, refresh_scheduler=scheduler,
        log_fn=lambda s: None)

    from repro.data.synthetic import SyntheticLM
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2)
    trainer = Trainer(train_step=manager.step,
                      init_state_fn=manager.init_state,
                      batch_fn=lambda s: ds.batch(jnp.int32(s)),
                      control_hook=manager.control_hook,
                      extra_state=manager, log_every=100)
    state = trainer.run(total_steps=10)
    assert int(state.step) == 10
    assert manager.n_rebuilds >= 1, "controllers never adopted a decision"
    assert np.isfinite(float(trainer.metrics_history[-1]["loss"]))
    # allocation moved and stayed within the weighted budget
    used = sum(leaves[p].rows * r for p, r in allocator.alloc.items())
    assert used <= allocator.budget


def test_leaf_inventory_orients_and_filters():
    cfg = _tiny()
    params_sds = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    leaves = leaf_inventory(params_sds)
    assert leaves, "no lowrank leaves found"
    for p, li in leaves.items():
        assert "embed" not in p and "norm" not in p
        assert li.cols <= li.rows


def test_summarize_collapses_stacked():
    st = SubspaceStats(
        captured_energy=jnp.asarray([0.2, 0.4]),
        topr_margin=jnp.asarray([0.1, 0.3]),
        index_overlap=jnp.float32(1.0),
        ef_norm=jnp.float32(2.0),
        rank_utilization=jnp.asarray([1.0, 0.5]))
    s = summarize(st)
    assert s["captured_energy"] == pytest.approx(0.3)
    assert s["rank_utilization"] == pytest.approx(0.75)


def test_telemetry_specs_replicate():
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import telemetry_specs
    tree = {"w": SubspaceStats(*([jnp.zeros((3,))] * 5))}
    specs = jax.tree.leaves(telemetry_specs(tree))
    assert specs and all(s == P() for s in specs)
