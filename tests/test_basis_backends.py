"""The pluggable orthogonal-basis backend suite (core/transforms.py).

Covers the backend property contract (orthonormality across awkward
orders, fast-path == matmul-path parity incl. the Hadamard odd-n
fallback), the registry-sourced unknown-kind errors, the process-wide
BasisCache (adaptive-rebuild hit counter), the per-backend captured-energy
telemetry invariant, the DCT bit-identity pin against the pre-refactor
outputs, and a reduced ZeRO-1 parity check per backend (8 forced host
devices — the CI multidevice job).
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import transforms as tr
from repro.core.projectors import Projector, projector_kinds, shared_basis_for
from repro.optim.common import Context
from repro.optim.projected_adam import ProjectedAdamRule

BACKENDS = tr.backend_kinds()
assert set(BACKENDS) >= {"dct", "dst", "hadamard", "randortho"}


# ---------------------------------------------------------------------------
# property suite: orthonormality + fast-path parity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [8, 17, 64])
@pytest.mark.parametrize("kind", BACKENDS)
def test_backend_matrix_orthonormal(kind, n):
    q = np.asarray(tr.get_backend(kind).matrix(n), dtype=np.float64)
    np.testing.assert_allclose(q.T @ q, np.eye(n), atol=5e-6,
                               err_msg=f"{kind} Q^T Q != I at n={n}")


@pytest.mark.parametrize("kind", BACKENDS)
def test_backend_matrix_orthonormal_4096_slice(kind):
    """At n=4096 the full n^2 Gram is wasteful; a random column slice of
    Q^T Q must still be the matching identity slice (and every sampled
    column unit-norm)."""
    n, k = 4096, 24
    q = np.asarray(tr.shared_basis(kind, n), dtype=np.float64)
    cols = np.random.default_rng(0).choice(n, size=k, replace=False)
    gram = q[:, cols].T @ q[:, cols]
    np.testing.assert_allclose(gram, np.eye(k), atol=2e-5,
                               err_msg=f"{kind} 4096-slice Gram != I")


@pytest.mark.parametrize("n", [8, 33, 64, 256])
@pytest.mark.parametrize("kind", BACKENDS)
def test_apply_fast_matches_matmul(kind, n):
    """``apply_fast`` (Makhoul FFT for dct, FHT butterfly for hadamard,
    matmul fallback elsewhere — incl. hadamard at non-power-of-two n)
    equals the matmul path to fp32 tolerance."""
    be = tr.get_backend(kind)
    x = jnp.asarray(
        np.random.default_rng(n).standard_normal((5, n)), jnp.float32)
    q = be.matrix(n)
    fast = np.asarray(be.apply_fast(x, q))
    mm = np.asarray(x @ q)
    np.testing.assert_allclose(fast, mm, atol=2e-5,
                               err_msg=f"{kind} fast != matmul at n={n}")


def test_fwht_equals_sylvester_matmul():
    """The in-jit butterfly is the exact (unnormalized) Sylvester WHT."""
    n = 64
    x = jnp.asarray(np.random.default_rng(1).standard_normal((3, n)),
                    jnp.float32)
    h = np.asarray(tr.hadamard_matrix(n)) * np.sqrt(n)   # ±1 Sylvester
    np.testing.assert_allclose(np.asarray(tr.fwht(x)), np.asarray(x) @ h,
                               atol=1e-4)
    with pytest.raises(ValueError, match="power-of-two"):
        tr.fwht(jnp.zeros((2, 12)))


def test_randortho_deterministic():
    a = np.asarray(tr.random_orthogonal_matrix(32))
    b = np.asarray(tr.random_orthogonal_matrix(32))
    np.testing.assert_array_equal(a, b)
    # diag(R) sign canonicalization picked a unique representative
    assert not np.allclose(a, np.asarray(tr.random_orthogonal_matrix(32, seed=1)))


# ---------------------------------------------------------------------------
# registry + error messages
# ---------------------------------------------------------------------------
def test_unknown_kind_is_eager_and_lists_allowed():
    with pytest.raises(ValueError, match="unknown projector kind 'wavelet'"):
        Projector(kind="wavelet", r=4)
    with pytest.raises(ValueError, match="allowed:.*dct.*svd"):
        Projector(kind="wavelet", r=4)
    with pytest.raises(ValueError, match="unknown projector"):
        ProjectedAdamRule(projector="wavelet")


def test_dispatch_paths_carry_registry_message(monkeypatch):
    """The defensive raises inside update/project/backproject must carry
    the same registry-sourced message as the eager validation — not the
    historical bare ``ValueError(self.kind)`` (a backend deregistered
    after construction is the only way to reach them)."""
    p = Projector(kind="dst", r=4)
    g = jnp.ones((6, 8), jnp.float32)
    state = p.init(g.shape)
    monkeypatch.delitem(tr._REGISTRY, "dst")
    for call in (lambda: p.update(g, state),
                 lambda: p.project(g, state),
                 lambda: p.backproject(jnp.ones((6, 4)), state, n=8),
                 lambda: p.basis_matrix(state, 8),
                 lambda: p.init(g.shape)):
        with pytest.raises(ValueError, match="unknown projector kind 'dst'"):
            call()
        with pytest.raises(ValueError, match="allowed:"):
            call()


def test_dense_projector_requests_no_shared_basis():
    """A dense-projector rule left at the default needs_shared_basis=True
    must not request a (nonexistent) 'svd' shared basis — stored-basis
    init worked for this configuration pre-refactor and must keep
    working."""
    from repro.optim.transform import as_optimizer, lowrank_project

    rule = ProjectedAdamRule(rank=4, projector="svd", residual="discard")
    assert rule.needs_shared_basis          # the default, deliberately
    assert rule.basis_sizes((12, 8)) == ()
    params = {"w": jnp.zeros((12, 8), jnp.float32)}
    state = as_optimizer(lowrank_project(rule)).init(params)   # no raise
    assert state.bases == {}


def test_register_backend_refuses_silent_overwrite():
    with pytest.raises(ValueError, match="already registered"):
        tr.register_backend(tr.DCTBackend())


def test_projector_kinds_tracks_registry():
    class _Stub(tr.BasisBackend):
        kind = "stub_basis"

        def matrix(self, n, dtype=jnp.float32):
            return jnp.eye(n, dtype=dtype)

    tr.register_backend(_Stub())
    try:
        assert "stub_basis" in projector_kinds()
        p = Projector(kind="stub_basis", r=2)          # eager check passes
        assert p.needs_shared_basis
    finally:
        del tr._REGISTRY["stub_basis"]


# ---------------------------------------------------------------------------
# projector roundtrip through every backend
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", BACKENDS)
def test_backend_projector_roundtrip(kind):
    m, n, r = 24, 16, 6
    p = Projector(kind=kind, r=r)
    g = jnp.asarray(np.random.default_rng(0).standard_normal((m, n)),
                    jnp.float32)
    q = shared_basis_for(kind, n)
    assert q is not None and q.shape == (n, n)
    state = p.update(g, p.init(g.shape), shared_q=q)
    assert state.dtype == jnp.int32 and state.shape == (r,)  # paper: r ints
    low = p.project(g, state, shared_q=q)
    rec = p.backproject(low, state, shared_q=q, n=n)
    assert rec.shape == (m, n)
    low2 = p.project(rec, state, shared_q=q)                 # P^2 = P
    np.testing.assert_allclose(np.asarray(low2), np.asarray(low), atol=1e-4)


# ---------------------------------------------------------------------------
# captured-energy telemetry invariant, per backend
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fused", ["off", "on", "fft"])
@pytest.mark.parametrize("kind", BACKENDS)
def test_captured_energy_at_least_r_over_n(kind, fused):
    """Top-r selection of n orthogonal directions captures at least the
    mean share r/n of ||G||_F^2 (§4.1) — for *any* orthogonal basis."""
    from repro.telemetry.stats import collect

    shape, r = (3, 24, 40), 8
    n = min(shape[-2:])
    rule = ProjectedAdamRule(rank=r, projector=kind, residual="ef",
                             ef_dtype="q8", fused=fused,
                             needs_shared_basis=True)
    state = rule.init(shape, jnp.float32)
    g = jnp.asarray(np.random.default_rng(5).standard_normal(shape),
                    jnp.float32)

    with collect() as col:
        @jax.jit
        def step(g, state):
            ctx = Context(step=jnp.int32(1), bases={},
                          key=jax.random.PRNGKey(0),
                          stats=col.scope("w"))
            d, s = rule.update(g, state, jnp.zeros(shape, jnp.float32), ctx)
            return d, s, col.tree()          # stats ride out as jit outputs

        _, _, tel = step(g, state)
    stats = jax.device_get(tel)["w"]
    cap = np.asarray(stats.captured_energy)
    assert cap.shape == shape[:-2]
    assert np.all(cap >= r / n - 1e-5), (kind, fused, cap, r / n)
    assert np.all(cap <= 1.0 + 1e-5)


# ---------------------------------------------------------------------------
# fused execution parity for the non-dct backends
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(24, 40), (3, 24, 40), (33, 17)],
                         ids=["2d", "stacked", "odd"])
@pytest.mark.parametrize("kind", ["dst", "hadamard", "randortho"])
def test_fused_matches_reference_new_backends(kind, shape):
    """"on" (Pallas interpret) and "fft" (backend fast transform) must
    match the "off" reference through the state feedback loop — the same
    contract tests/test_fused_step.py pins for dct."""
    def run(rule, n_steps=3, seed=0):
        rng = np.random.default_rng(seed)
        state = rule.init(shape, jnp.float32)
        param = jnp.zeros(shape, jnp.float32)

        @functools.partial(jax.jit)
        def step_fn(g, state, step):
            ctx = Context(step=step, bases={}, key=jax.random.PRNGKey(7))
            return rule.update(g, state, param, ctx)

        outs = []
        for t in range(1, n_steps + 1):
            g = jnp.asarray(rng.standard_normal(shape), jnp.float32)
            d, state = step_fn(g, state, jnp.asarray(t, jnp.int32))
            outs.append(np.asarray(d))
        return outs

    base = ProjectedAdamRule(rank=8, projector=kind, residual="ef",
                             ef_dtype="q8", fused="off",
                             needs_shared_basis=True)
    ref = run(base)
    for mode in ("on", "fft"):
        got = run(dataclasses.replace(base, fused=mode))
        for t, (a, b) in enumerate(zip(ref, got)):
            tol = 3e-4 if t == 0 else 5e-3
            np.testing.assert_allclose(
                b, a, atol=tol, rtol=5e-3,
                err_msg=f"{kind}/{mode} step {t + 1}")


# ---------------------------------------------------------------------------
# DCT bit-identity pin (pre-refactor golden digests)
# ---------------------------------------------------------------------------
# Recorded from the hardcoded-dct implementation at PR-4 head (commit
# 0bbcf75): per fused mode and shape, [sum(d_t) for t=1..3] +
# [sum(|d_t|) for t=1..3] of the rank-8 q8-EF T_u=2 update, each reduced
# in float64 and cast to fp32. Bitwise-identical updates <=> identical
# digests; any numeric drift in the refactored dct path trips this.
_DCT_GOLDEN = {
    ("off", "2d"): [-1.8221326172351837e-06, -4.248169716447592e-06, -43.813323974609375, 449.09912109375, 316.1246643066406, 283.2120666503906],
    ("off", "stacked"): [-19.595788955688477, -4.822482585906982, 6.259047985076904, 1346.761474609375, 930.4658203125, 858.7440185546875],
    ("off", "odd"): [-5.448237061500549e-08, 1.3905810192227364e-06, -7.9016594886779785, 310.8307189941406, 212.29336547851562, 195.54966735839844],
    ("off", "transposed"): [-1.8891296349465847e-06, -1.1588454071898013e-06, -25.552444458007812, 448.7791748046875, 316.75274658203125, 277.8719177246094],
    ("on", "2d"): [-1.8221326172351837e-06, -4.248169716447592e-06, -43.813323974609375, 449.09912109375, 316.1246643066406, 283.2120666503906],
    ("on", "stacked"): [-19.595788955688477, -4.822482585906982, 6.259047985076904, 1346.761474609375, 930.4658203125, 858.7440185546875],
    ("on", "odd"): [-5.448237061500549e-08, 1.3905810192227364e-06, -7.9016594886779785, 310.8307189941406, 212.29336547851562, 195.54966735839844],
    ("on", "transposed"): [-1.8891296349465847e-06, -1.1588454071898013e-06, -25.552444458007812, 448.7791748046875, 316.75274658203125, 277.8719177246094],
    ("fft", "2d"): [-4.7637149691581726e-07, -4.7245994210243225e-06, -43.813323974609375, 449.09912109375, 316.1246643066406, 283.2120666503906],
    ("fft", "stacked"): [-19.59578514099121, -4.822486400604248, 6.259049892425537, 1346.7613525390625, 930.4658203125, 858.7440185546875],
    ("fft", "odd"): [-3.421446308493614e-07, 1.598498784005642e-06, -7.901658535003662, 310.8307189941406, 212.29336547851562, 195.54965209960938],
    ("fft", "transposed"): [-4.318950232118368e-06, -7.642402124474756e-07, -25.55244255065918, 448.7791748046875, 316.75274658203125, 277.8719177246094],
}
_PIN_SHAPES = {"2d": (24, 40), "stacked": (3, 24, 40), "odd": (33, 17),
               "transposed": (16, 48)}


@pytest.mark.parametrize("mode", ["off", "on", "fft"])
@pytest.mark.parametrize("shape_id", list(_PIN_SHAPES))
def test_dct_bit_identical_to_pre_refactor(mode, shape_id):
    shape = _PIN_SHAPES[shape_id]
    rule = ProjectedAdamRule(rank=8, projector="dct", residual="ef",
                             ef_dtype="q8", update_interval=2, fused=mode)
    rng = np.random.default_rng(0)
    state = rule.init(shape, jnp.float32)
    param = jnp.zeros(shape, jnp.float32)

    @jax.jit
    def step_fn(g, state, step):
        ctx = Context(step=step, bases={}, key=jax.random.PRNGKey(7))
        return rule.update(g, state, param, ctx)

    sums, abssums = [], []
    for t in range(1, 4):
        g = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        d, state = step_fn(g, state, jnp.asarray(t, jnp.int32))
        d = np.asarray(d)
        sums.append(float(np.float32(d.astype(np.float64).sum())))
        abssums.append(float(np.float32(np.abs(d).astype(np.float64).sum())))
    np.testing.assert_array_equal(
        np.asarray(sums + abssums, np.float64),
        np.asarray(_DCT_GOLDEN[(mode, shape_id)], np.float64),
        err_msg=f"dct update drifted from pre-refactor outputs "
                f"({mode}/{shape_id})")


# ---------------------------------------------------------------------------
# BasisCache: adaptive rebuilds must hit, not recompute
# ---------------------------------------------------------------------------
def test_basis_cache_hit_on_adaptive_rebuild():
    """telemetry/adaptive.py rebuilds the optimizer via
    ``lowrank_project(overrides=...)`` + ``optimizer.init``; the second
    init must serve every shared basis from the cache (counter-observable)
    instead of recomputing the n×n matrices."""
    from repro.optim.api import get_optimizer

    params = {"w": jnp.zeros((48, 32), jnp.float32),
              "w2": jnp.zeros((48, 24), jnp.float32)}
    cache = tr.basis_cache()
    cache.clear()

    def make_optimizer(overrides=None):
        return get_optimizer("dct_adamw", lr=1e-2, rank=8,
                             overrides=overrides)

    opt = make_optimizer()
    opt.init(params)
    first = cache.stats()
    assert first["misses"] >= 2 and first["entries"] >= 2   # 32 and 24

    # the adaptive-controller cycle: new overrides -> rebuilt optimizer ->
    # fresh init for state migration (adaptive.AdaptiveOptimizerManager)
    opt2 = make_optimizer({"w": {"rank": 12}})
    opt2.init(params)
    second = cache.stats()
    assert second["misses"] == first["misses"], \
        "adaptive rebuild recomputed a shared basis (cache miss)"
    assert second["hits"] >= first["hits"] + 2, \
        "adaptive rebuild did not hit the BasisCache"


def test_basis_cache_serves_all_kinds():
    cache = tr.basis_cache()
    for kind in BACKENDS:
        a = tr.shared_basis(kind, 16)
        b = tr.shared_basis(kind, 16)
        # value-identical but a *fresh* device buffer per get — entries
        # land in donated optimizer state, so sharing one buffer would
        # leave the cache deleted after the first donating step
        assert a is not b
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert cache.stats()["hits"] >= len(BACKENDS)


# ---------------------------------------------------------------------------
# reduced ZeRO-1 parity per backend (CI multidevice job)
# ---------------------------------------------------------------------------
@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (CI multidevice job forces "
                           "8 host devices via XLA_FLAGS)")
@pytest.mark.parametrize("kind", ["dst", "hadamard", "randortho"])
def test_zero_parity_new_backends_multidevice(kind):
    """Sharded vs replicated updates bit-identical (fp32) for every
    non-dct backend — the reduced companion of tests/test_zero_parity.py
    (which pins dct exhaustively)."""
    from repro.launch.mesh import make_mesh
    from repro.optim.transform import matrix_optimizer
    from repro.parallel.compat import set_mesh
    from repro.parallel.zero import ZeroConfig

    rule = ProjectedAdamRule(rank=8, projector=kind, residual="ef",
                             ef_dtype="q8", fused="off",
                             needs_shared_basis=True)
    assert rule.zero_shardable
    params = {"w": jnp.zeros((64, 32), jnp.float32)}
    grads = {"w": jnp.asarray(
        np.random.default_rng(0).standard_normal((64, 32)), jnp.float32)}
    rep = matrix_optimizer(rule, 1e-2)
    zo = matrix_optimizer(rule, 1e-2, zero=ZeroConfig(mode="1",
                                                      axes=("data",)))
    u_rep, _ = jax.jit(rep.update)(grads, rep.init(params), params)
    with set_mesh(make_mesh((8,), ("data",))):
        u_z, _ = jax.jit(zo.update)(grads, zo.init(params), params)
    a = np.asarray(u_rep["w"])
    b = np.asarray(jax.device_get(u_z["w"]))
    assert a.tobytes() == b.tobytes(), \
        f"{kind}: sharded update differs from replicated (max " \
        f"{np.abs(a - b).max()})"
