"""opt_state_specs coverage: combinator state x layouts x runtimes.

The sharding layer must derive placements for the optimizer state of BOTH
runtimes — the legacy monolithic harness (``HarnessState``) and the
transform-chain runtime (``ChainState`` nesting chain tuples / partition
dicts / inject-hyperparams records) — under all three layout policies,
including the q8 error-feedback buffers (int8 payload follows the
transpose-oriented param spec, per-row scales keep the row spec) and the
ZeRO-1 placement mode (DESIGN.md §9).

Spec derivation is pure shape/name logic, so a lightweight mesh stand-in
(axis_names + shape) suffices — no forced host devices.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.optim.common import make_matrix_optimizer
from repro.optim.projected_adam import ProjectedAdamRule
from repro.optim.transform import (
    as_optimizer,
    inject_hyperparams,
    matrix_optimizer,
)
from repro.parallel import sharding as sh
from repro.parallel.zero import ZeroConfig


@dataclasses.dataclass(frozen=True)
class FakeMesh:
    """Just enough mesh surface for spec derivation (names + sizes)."""

    sizes: tuple[tuple[str, int], ...] = (("pod", 2), ("data", 4),
                                          ("model", 2))

    @property
    def axis_names(self):
        return tuple(n for n, _ in self.sizes)

    @property
    def shape(self):
        return dict(self.sizes)


MESH = FakeMesh()
DP = ("pod", "data")

PARAMS = {
    "blocks": {
        "wq": jnp.zeros((3, 64, 48), jnp.float32),   # stacked, rows first
        "wo": jnp.zeros((48, 64), jnp.float32),      # transposed orientation
    },
    "embed": jnp.zeros((100, 64), jnp.float32),      # full-rank route
    "norm": jnp.zeros((64,), jnp.float32),           # 1D full-rank route
}

RULE = ProjectedAdamRule(rank=8, residual="ef", ef_dtype="q8")


def _build(runtime: str):
    if runtime == "legacy":
        return make_matrix_optimizer(RULE, 0.01)
    return matrix_optimizer(RULE, 0.01)


def _lowrank_leaf(state, runtime: str, name: str):
    leaves = state.leaves
    if runtime == "legacy":
        return leaves["blocks"][name]
    return leaves[0]["lowrank"]["blocks"][name]


@pytest.mark.parametrize("layout", sh.LAYOUTS)
@pytest.mark.parametrize("runtime", ["legacy", "chain"])
def test_opt_state_specs_all_layouts(runtime, layout):
    opt = _build(runtime)
    state = jax.eval_shape(opt.init, PARAMS)
    with sh.use_policy(layout=layout):
        p_specs = sh.params_specs(PARAMS, MESH)
        o_specs = sh.opt_state_specs(state, PARAMS, p_specs)

    # runtime roots always replicate
    assert o_specs.step == P() and o_specs.key == P()
    wq_p = p_specs["blocks"]["wq"]
    wq = _lowrank_leaf(o_specs, runtime, "wq")

    if layout == "pure_dp":
        # params replicated -> every state leaf replicated (specs may be
        # padded with explicit Nones)
        assert all(all(ax is None for ax in s) for s in jax.tree.leaves(
            o_specs, is_leaf=lambda x: isinstance(x, P)))
        return

    # low-rank moments: row spec kept, rank dim replicated
    assert wq.m == P(wq_p[0], wq_p[1], None) == wq.v
    # q8 EF: int8 payload is param-oriented (same shape -> same spec);
    # per-row scales keep the row spec
    assert wq.ef.q == wq_p
    assert wq.ef.scale == P(wq_p[0], wq_p[1], None)
    # indices / inner step replicate
    assert wq.proj == P() and wq.inner_step == P()

    # transposed leaf: EF is stored oriented (64, 48) against the (48, 64)
    # param -> the spec swaps the trailing axes of the param spec; the
    # moments' oriented row dim matches no param dim -> shape matching
    # replicates them (the ZeRO mode below is what splits these rows)
    wo_p = p_specs["blocks"]["wo"]
    wo = _lowrank_leaf(o_specs, runtime, "wo")
    assert wo.ef.q == P(wo_p[1], wo_p[0])
    assert wo.m == P(None, None)

    # full-rank Adam moments follow the param spec exactly
    if runtime == "legacy":
        emb = o_specs.leaves["embed"]
    else:
        emb = o_specs.leaves[0]["full"]["embed"]
    assert emb.mom.m == p_specs["embed"] == emb.mom.v


@pytest.mark.parametrize("runtime", ["legacy", "chain"])
@pytest.mark.parametrize("layout", sh.LAYOUTS)
def test_opt_state_specs_zero_mode(runtime, layout):
    """ZeRO-1 placement: eligible leaves partition rows over the DP axes
    regardless of layout; indices and ineligible leaves replicate."""
    opt = _build(runtime)
    state = jax.eval_shape(opt.init, PARAMS)
    with sh.use_policy(layout=layout):
        p_specs = sh.params_specs(PARAMS, MESH)
        o_specs = sh.opt_state_specs(state, PARAMS, p_specs,
                                     zero=ZeroConfig(mode="1"), mesh=MESH)

    wq = _lowrank_leaf(o_specs, runtime, "wq")
    assert wq.m == P(None, DP, None) == wq.v
    assert wq.ef.q == P(None, DP, None)       # rows, NOT the tp-matched spec
    assert wq.ef.scale == P(None, DP, None)
    assert wq.proj == P() and wq.inner_step == P()
    # transposed leaf: oriented rows (64) split evenly too
    wo = _lowrank_leaf(o_specs, runtime, "wo")
    assert wo.m == P(DP, None) and wo.ef.q == P(DP, None)


def test_opt_state_specs_zero_ineligible_rows():
    """Rows not divisible by the shard count keep the shape-matched spec."""
    params = {"blocks": {"wq": jnp.zeros((36, 20), jnp.float32)}}
    opt = matrix_optimizer(RULE, 0.01)
    state = jax.eval_shape(opt.init, params)
    with sh.use_policy(layout="fsdp_tp"):
        p_specs = sh.params_specs(params, MESH)
        o_specs = sh.opt_state_specs(state, params, p_specs,
                                     zero=ZeroConfig(mode="1"), mesh=MESH)
    wq = o_specs.leaves[0]["lowrank"]["blocks"]["wq"]
    p = p_specs["blocks"]["wq"]
    assert wq.m == P(p[0], None)              # 36 % 8 != 0 -> shape-matched


def test_opt_state_specs_inject_hyperparams():
    """The walk descends inject-hyperparams records: fp32 hyper scalars
    replicate, the inner partition/chain state still derives per-leaf."""
    from repro.optim.projected_adam import dct_adamw_transform

    params = {"blocks": dict(PARAMS["blocks"])}   # matrix-leaf pipeline
    t = inject_hyperparams(dct_adamw_transform)(lr=0.01, rank=8)
    opt = as_optimizer(t)
    state = jax.eval_shape(opt.init, params)
    with sh.use_policy(layout="fsdp_tp"):
        p_specs = sh.params_specs(params, MESH)
        o_specs = sh.opt_state_specs(state, params, p_specs)
    assert o_specs.leaves.hyperparams["lr"] == P()
    wq = o_specs.leaves.inner[0]["blocks"]["wq"]
    assert wq.m == P(None, DP, None)
