"""Block allocator + paged-cache unit tests (pure host logic)."""
import numpy as np
import pytest

from repro.serve.kv_cache import (BlockAllocator, OutOfBlocksError,
                                  blocks_for)


def test_blocks_for():
    assert blocks_for(0, 8) == 0
    assert blocks_for(1, 8) == 1
    assert blocks_for(8, 8) == 1
    assert blocks_for(9, 8) == 2


def test_alloc_free_roundtrip():
    a = BlockAllocator(num_blocks=8, block_size=4)
    t = a.alloc("a", 10)            # 3 blocks
    assert len(t) == 3 and a.free_blocks == 5
    assert a.length("a") == 10
    assert a.free("a") == 3
    assert a.free_blocks == 8


def test_block_reuse_after_free_is_fifo():
    """Freed blocks go to the tail; reuse order is deterministic."""
    a = BlockAllocator(num_blocks=4, block_size=4)
    t1 = a.alloc("a", 8)            # blocks [0, 1]
    t2 = a.alloc("b", 8)            # blocks [2, 3]
    assert t1 == [0, 1] and t2 == [2, 3]
    a.free("a")                     # free list: [0, 1]
    t3 = a.alloc("c", 8)
    assert t3 == [0, 1]             # a's blocks, in order
    a.free("b")
    a.free("c")
    t4 = a.alloc("d", 16)
    assert t4 == [2, 3, 0, 1]       # FIFO through both frees


def test_out_of_blocks_raises_and_can_alloc_guards():
    a = BlockAllocator(num_blocks=2, block_size=4)
    a.alloc("a", 8)
    assert not a.can_alloc(1)
    with pytest.raises(OutOfBlocksError):
        a.alloc("b", 1)
    # the failed alloc must not leak partial state
    assert a.free_blocks == 0 and "b" not in a._tables
    a.free("a")
    assert a.can_alloc(8)


def test_extend_grows_and_backpressures():
    a = BlockAllocator(num_blocks=3, block_size=4)
    a.alloc("a", 4)
    fresh = a.extend("a", 9)        # 1 -> 3 blocks
    assert len(fresh) == 2 and a.length("a") == 9
    assert a.extend("a", 10) == []  # fits in the tail block
    with pytest.raises(OutOfBlocksError):
        a.extend("a", 13)
    # the failed extend must not leak partial state
    assert a.free_blocks == 0 and len(a.table("a")) == 3


def test_double_alloc_rejected():
    a = BlockAllocator(num_blocks=4, block_size=4)
    a.alloc("a", 4)
    with pytest.raises(ValueError):
        a.alloc("a", 4)


def test_stats_utilization_fragmentation():
    a = BlockAllocator(num_blocks=8, block_size=8)
    a.alloc("a", 9)                 # 2 blocks for 9 tokens
    s = a.stats()
    assert s["used_blocks"] == 2 and s["free_blocks"] == 6
    assert s["held_tokens"] == 9
    assert s["utilization"] == pytest.approx(9 / 16)
    assert s["fragmentation"] == pytest.approx(1 - 9 / 16)
    a.free("a")
    s = a.stats()
    assert s["utilization"] == 0.0 and s["fragmentation"] == 0.0


def test_paged_cache_table_and_sizing():
    import jax
    from repro.configs.registry import SMOKES
    from repro.serve.kv_cache import PagedCacheConfig, PagedKVCache

    cfg = SMOKES["qwen2.5-32b"]
    cc = PagedCacheConfig(block_size=4, num_blocks=16, max_blocks_per_seq=4)
    cache = PagedKVCache(cfg, cc, num_slots=2)
    # pools mirror the schedule segments with a leading repeats axis
    for leaf in jax.tree.leaves(cache.pools):
        assert leaf.shape[1:3] == (16, 4)
    cache.allocator.alloc("r", 6)
    cache.bind_slot(1, "r")
    tab = np.asarray(cache.block_table())
    assert tab.shape == (2, 4)
    assert (tab[0] == 0).all()
    assert (tab[1, :2] == cache.allocator.table("r")).all()
    cache.clear_slot(1)
    assert (np.asarray(cache.block_table()) == 0).all()
    # the paged pool is strictly smaller than a dense cache of the same
    # (num_slots, max_seq_len) capacity whenever num_blocks < slots * maxb
    assert cache.cache_bytes() < cache.dense_bytes_equivalent() * (
        cc.num_blocks / (2 * cc.max_blocks_per_seq)) * 1.01


def test_paged_cache_rejects_unpaged_families():
    from repro.configs.registry import SMOKES
    from repro.serve.kv_cache import (PagedCacheConfig, PagedKVCache,
                                      paged_supported)

    cfg = SMOKES["deepseek-v3-671b"]          # MLA latents: dense path only
    assert not paged_supported(cfg)
    cc = PagedCacheConfig(block_size=4, num_blocks=8, max_blocks_per_seq=2)
    with pytest.raises(ValueError, match="paged"):
        PagedKVCache(cfg, cc, num_slots=1)
