"""Multi-device numerics: the §Perf optimizations must not change math.

Runs in a subprocess with 8 forced host devices (device count is locked at
first jax init, so the main test process can't do this itself). Checks:
  * sp_blockwise_attention (shard_map, S over `model`) == plain blockwise
    attention under a (2, 4) mesh;
  * a full train_step gives the same loss with attn_sp on/off;
  * pure_dp and fsdp_tp layouts give the same loss.
"""
import os
import subprocess
import sys
import textwrap

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_mesh
    from repro.parallel.compat import set_mesh
    from repro.models.layers import blockwise_attention, sp_blockwise_attention
    from repro.models.config import ModelConfig
    from repro.models import transformer as T
    from repro.optim.api import get_optimizer
    from repro.parallel import sharding as sh
    from repro.train.steps import init_state, make_train_step

    mesh = make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)

    # ---- 1. SP attention numerics ----------------------------------------
    b, s, hq, hkv, hd = 2, 64, 6, 3, 16      # heads don't divide model=4
    q = jnp.asarray(rng.standard_normal((b, s, hq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
    with set_mesh(mesh):
        ref = jax.jit(lambda q, k, v: blockwise_attention(
            q, k, v, causal=True, q_chunk=16, kv_chunk=16))(q, k, v)
        out = jax.jit(lambda q, k, v: sp_blockwise_attention(
            q, k, v, causal=True, q_chunk=16, kv_chunk=16))(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)
    print("sp-attention parity OK")

    # ---- 2. train_step loss parity: attn_sp on/off ------------------------
    cfg = ModelConfig(
        name="tiny", family="dense", d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab_size=64, schedule=((("attn",), 2),),
        param_dtype="float32", compute_dtype="float32", remat=False,
        q_chunk=16, kv_chunk=16)
    opt = get_optimizer("trion", lr=1e-3, rank=8)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, 64, (8, 64)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, 64, (8, 64)), jnp.int32),
    }
    losses = {}
    for sp in (False, True):
        c = dataclasses.replace(cfg, attn_sp=sp)
        with set_mesh(mesh):
            state = init_state(c, opt, jax.random.PRNGKey(0))
            _, m = jax.jit(make_train_step(c, opt))(state, batch)
            losses[sp] = float(m["loss"])
    assert abs(losses[True] - losses[False]) < 1e-4, losses
    print("attn_sp loss parity OK", losses)

    # ---- 3. layout policy loss parity -------------------------------------
    vals = {}
    for layout in ("fsdp_tp", "pure_dp"):
        with sh.use_policy(layout=layout), set_mesh(mesh):
            state = init_state(cfg, opt, jax.random.PRNGKey(0))
            _, m = jax.jit(make_train_step(cfg, opt))(state, batch)
            vals[layout] = float(m["loss"])
    assert sh.layout_policy() == "fsdp_tp"   # scoped policy restored
    assert abs(vals["pure_dp"] - vals["fsdp_tp"]) < 1e-4, vals
    print("layout loss parity OK", vals)

    # ---- 4. decode_tp logits parity (incl. MoE f-sliced experts) ----------
    moe_cfg = ModelConfig(
        name="tinymoe", family="moe", d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab_size=64, schedule=((("attn", "attn_moe"), 2),),
        n_experts=4, moe_top_k=2, moe_d_ff=16, capacity_factor=8.0,
        param_dtype="float32", compute_dtype="float32", remat=False,
        q_chunk=16, kv_chunk=16)
    params = T.init_params(moe_cfg, jax.random.PRNGKey(3))
    tok = jnp.asarray(rng.integers(0, 64, (4,)), jnp.int32)
    outs = {}
    for layout in ("fsdp_tp", "decode_tp"):
        with sh.use_policy(layout=layout), set_mesh(mesh):
            cache = T.init_cache(moe_cfg, 4, 16)
            lg, _ = jax.jit(
                lambda p, c, t: T.decode_step(p, c, t, jnp.int32(0), moe_cfg)
            )(params, cache, tok)
            outs[layout] = np.asarray(lg)
    np.testing.assert_allclose(outs["decode_tp"], outs["fsdp_tp"],
                               atol=2e-5, rtol=1e-4)
    print("decode_tp logits parity OK")

    # ---- 5. elastic checkpoint restore across meshes ----------------------
    import tempfile
    from repro.train.checkpoint import CheckpointManager
    w = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    state = {"w": w, "step": jnp.int32(7)}
    cm = CheckpointManager(tempfile.mkdtemp(prefix="ck_"), keep=2)
    cm.save(7, state)                      # saved mesh-agnostic
    # restore onto a DIFFERENT mesh with explicit shardings (elastic)
    mesh2 = make_mesh((4, 2), ("data", "model"))
    shardings = {"w": NamedSharding(mesh2, P("data", "model")),
                 "step": NamedSharding(mesh2, P())}
    target = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          state)
    restored = cm.restore(7, target, shardings=shardings)
    np.testing.assert_allclose(np.asarray(restored["w"]), np.asarray(w))
    assert restored["w"].sharding.spec == P("data", "model")
    print("elastic restore OK")
""")


def test_multidevice_parity():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "sp-attention parity OK" in proc.stdout
    assert "attn_sp loss parity OK" in proc.stdout
    assert "layout loss parity OK" in proc.stdout
    assert "decode_tp logits parity OK" in proc.stdout
    assert "elastic restore OK" in proc.stdout
