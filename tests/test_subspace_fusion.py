"""Subspace-fused muon/trion/dion: equivalence + dispatch pins (DESIGN.md §14).

Two contracts:

1. **Full-rank subspace == full-space.** With r = min(m, n) the selection
   returns a permutation P of all columns, so the low-rank factor is
   ``B Q P`` for orthogonal ``Q P`` — Newton–Schulz commutes with right
   orthogonal factors (NS(XQ) = NS(X)Q) and the back-projection cancels
   the permutation, so the subspace path must reproduce the full-space
   update up to fp rounding (measured ~1e-8; pinned at 1e-6).  Trion at
   full rank reduces to heavy-ball muon: its EF recursion
   ``M_t = mu*(M_{t-1}+G_t)`` makes ``B_t`` follow muon's
   nesterov=False momentum recursion exactly.

2. **Dispatch.** When fused="on", muon/trion must reach the Pallas
   kernels *through* partition/chain (PR-1-style spy — the regression the
   CI bench also gates), and every Newton–Schulz call in the subspace
   path must run on rank-sized blocks (min trailing dim == r), never on
   the full (m, n) momentum.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fused_step
from repro.optim.api import get_optimizer

L, M, N = 3, 24, 40


def _params():
    rng = np.random.default_rng(0)
    return {
        "w": jnp.asarray(rng.standard_normal((L, M, N)) * 0.3, jnp.float32),
        "odd": jnp.asarray(rng.standard_normal((33, 20)) * 0.3, jnp.float32),
    }


def _grads(t, params):
    r = np.random.default_rng(50 + t)
    return {k: jnp.asarray(r.standard_normal(v.shape) * 0.05, jnp.float32)
            for k, v in params.items()}


def _run(opt, params, steps=3):
    st = opt.init(params)
    for t in range(steps):
        u, st = jax.jit(opt.update)(_grads(t, params), st, params)
    return u


# ---------------------------------------------------------------------------
# full-rank subspace == full-space (NS(XQ) = NS(X)Q through the whole chain)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fused", ["off", "on"])
def test_muon_fullrank_subspace_matches_fullspace(fused):
    params = _params()
    full = get_optimizer("muon", lr=1e-2, fused=fused)
    sub = get_optimizer("muon", lr=1e-2, rank=max(M, N), fused=fused)
    uf, us = _run(full, params), _run(sub, params)
    for k in params:
        np.testing.assert_allclose(np.asarray(us[k]), np.asarray(uf[k]),
                                   atol=1e-6, err_msg=f"fused={fused} {k}")


@pytest.mark.parametrize("fused", ["off", "on"])
def test_trion_fullrank_matches_heavyball_muon(fused):
    """B_t = mu*B_{t-1} + G_t == muon's nesterov=False momentum, and at
    full rank the EF reconstruction is exact, so updates coincide."""
    params = _params()
    mu = get_optimizer("muon", lr=1e-2, nesterov=False, fused=fused)
    tr = get_optimizer("trion", lr=1e-2, rank=max(M, N), fused=fused)
    um, ut = _run(mu, params), _run(tr, params)
    for k in params:
        np.testing.assert_allclose(np.asarray(ut[k]), np.asarray(um[k]),
                                   atol=1e-6, err_msg=f"fused={fused} {k}")


# ---------------------------------------------------------------------------
# dispatch spies: fused kernels reached THROUGH partition -> lowrank_project
# ---------------------------------------------------------------------------
def _spy(monkeypatch, record_shapes=False):
    calls = {"select": 0, "ns": 0, "ns_shapes": []}
    orig_sel = fused_step.select_and_project
    orig_ns = fused_step.ops.newton_schulz_op

    def sel_spy(*a, **kw):
        calls["select"] += 1
        return orig_sel(*a, **kw)

    def ns_spy(x, **kw):
        calls["ns"] += 1
        calls["ns_shapes"].append(tuple(x.shape))
        return orig_ns(x, **kw)

    monkeypatch.setattr(fused_step, "select_and_project", sel_spy)
    monkeypatch.setattr(fused_step.ops, "newton_schulz_op", ns_spy)
    return calls


@pytest.mark.parametrize("name,kw", [
    ("muon", {"rank": 8}),
    ("trion", {"rank": 8}),
])
def test_fused_kernels_reached_through_partition(monkeypatch, name, kw):
    """Hard-fails if muon/trion stop routing through the fused one-pass
    select+project and the Pallas Newton–Schulz."""
    calls = _spy(monkeypatch)
    params = _params()
    opt = get_optimizer(name, lr=1e-2, fused="on", **kw)
    st = opt.init(params)
    upd, _ = opt.update(_grads(0, params), st, params)  # unjitted: trace spies
    assert calls["select"] > 0, f"{name}: select+project kernel not reached"
    assert calls["ns"] > 0, f"{name}: newton_schulz kernel not reached"
    for k in params:
        assert np.isfinite(np.asarray(upd[k])).all()


def test_dion_ns_for_qr_reached(monkeypatch):
    """dion fused='on' substitutes NS for QR (SUMO) — the kernel must fire."""
    calls = _spy(monkeypatch)
    params = _params()
    opt = get_optimizer("dion", lr=1e-2, rank=8, fused="on")
    st = opt.init(params)
    upd, _ = opt.update(_grads(0, params), st, params)
    assert calls["ns"] > 0, "dion: newton_schulz kernel not reached"
    for k in params:
        assert np.isfinite(np.asarray(upd[k])).all()


@pytest.mark.parametrize("name", ["muon", "trion", "dion"])
def test_ns_runs_on_rank_sized_blocks(monkeypatch, name):
    """The tentpole shape pin: every NS call in the subspace path sees a
    rank-sized block — min trailing dim == r, never the full (m, n)."""
    r = 8
    calls = _spy(monkeypatch)
    params = _params()
    opt = get_optimizer(name, lr=1e-2, rank=r, fused="on")
    st = opt.init(params)
    opt.update(_grads(0, params), st, params)
    assert calls["ns_shapes"], f"{name}: no NS calls recorded"
    for shape in calls["ns_shapes"]:
        assert min(shape[-2:]) == r, (
            f"{name}: NS ran on {shape}, not a rank-{r} block")
        assert max(shape[-2:]) < M * N, shape
