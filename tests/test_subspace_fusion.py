"""Subspace-fused muon/trion/dion: equivalence + dispatch pins (DESIGN.md §14).

Two contracts:

1. **Full-rank subspace == full-space.** With r = min(m, n) the selection
   returns a permutation P of all columns, so the low-rank factor is
   ``B Q P`` for orthogonal ``Q P`` — Newton–Schulz commutes with right
   orthogonal factors (NS(XQ) = NS(X)Q) and the back-projection cancels
   the permutation, so the subspace path must reproduce the full-space
   update up to fp rounding (measured ~1e-8; pinned at 1e-6).  Trion at
   full rank reduces to heavy-ball muon: its EF recursion
   ``M_t = mu*(M_{t-1}+G_t)`` makes ``B_t`` follow muon's
   nesterov=False momentum recursion exactly.

2. **Dispatch.** When fused="on", muon/trion must reach the Pallas
   kernels *through* partition/chain (PR-1-style spy — the regression the
   CI bench also gates), and every Newton–Schulz call in the subspace
   path must run on rank-sized blocks (min trailing dim == r), never on
   the full (m, n) momentum.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fused_step
from repro.core.newton_schulz import newton_schulz
from repro.optim.api import get_optimizer
from repro.telemetry.stats import collect

L, M, N = 3, 24, 40


def _params():
    rng = np.random.default_rng(0)
    return {
        "w": jnp.asarray(rng.standard_normal((L, M, N)) * 0.3, jnp.float32),
        "odd": jnp.asarray(rng.standard_normal((33, 20)) * 0.3, jnp.float32),
    }


def _grads(t, params):
    r = np.random.default_rng(50 + t)
    return {k: jnp.asarray(r.standard_normal(v.shape) * 0.05, jnp.float32)
            for k, v in params.items()}


def _run(opt, params, steps=3):
    st = opt.init(params)
    for t in range(steps):
        u, st = jax.jit(opt.update)(_grads(t, params), st, params)
    return u


# ---------------------------------------------------------------------------
# full-rank subspace == full-space (NS(XQ) = NS(X)Q through the whole chain)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fused", ["off", "on"])
def test_muon_fullrank_subspace_matches_fullspace(fused):
    params = _params()
    full = get_optimizer("muon", lr=1e-2, fused=fused)
    sub = get_optimizer("muon", lr=1e-2, rank=max(M, N), fused=fused)
    uf, us = _run(full, params), _run(sub, params)
    for k in params:
        np.testing.assert_allclose(np.asarray(us[k]), np.asarray(uf[k]),
                                   atol=1e-6, err_msg=f"fused={fused} {k}")


@pytest.mark.parametrize("fused", ["off", "on"])
def test_trion_fullrank_matches_heavyball_muon(fused):
    """B_t = mu*B_{t-1} + G_t == muon's nesterov=False momentum, and at
    full rank the EF reconstruction is exact, so updates coincide."""
    params = _params()
    mu = get_optimizer("muon", lr=1e-2, nesterov=False, fused=fused)
    tr = get_optimizer("trion", lr=1e-2, rank=max(M, N), fused=fused)
    um, ut = _run(mu, params), _run(tr, params)
    for k in params:
        np.testing.assert_allclose(np.asarray(ut[k]), np.asarray(um[k]),
                                   atol=1e-6, err_msg=f"fused={fused} {k}")


# ---------------------------------------------------------------------------
# dispatch spies: fused kernels reached THROUGH partition -> lowrank_project
# ---------------------------------------------------------------------------
def _spy(monkeypatch, record_shapes=False):
    calls = {"select": 0, "ns": 0, "ns_shapes": []}
    orig_sel = fused_step.select_and_project
    orig_ns = fused_step.ops.newton_schulz_op

    def sel_spy(*a, **kw):
        calls["select"] += 1
        return orig_sel(*a, **kw)

    def ns_spy(x, **kw):
        calls["ns"] += 1
        calls["ns_shapes"].append(tuple(x.shape))
        return orig_ns(x, **kw)

    monkeypatch.setattr(fused_step, "select_and_project", sel_spy)
    monkeypatch.setattr(fused_step.ops, "newton_schulz_op", ns_spy)
    return calls


@pytest.mark.parametrize("name,kw", [
    ("muon", {"rank": 8}),
    ("trion", {"rank": 8}),
])
def test_fused_kernels_reached_through_partition(monkeypatch, name, kw):
    """Hard-fails if muon/trion stop routing through the fused one-pass
    select+project and the Pallas Newton–Schulz."""
    calls = _spy(monkeypatch)
    params = _params()
    opt = get_optimizer(name, lr=1e-2, fused="on", **kw)
    st = opt.init(params)
    upd, _ = opt.update(_grads(0, params), st, params)  # unjitted: trace spies
    assert calls["select"] > 0, f"{name}: select+project kernel not reached"
    assert calls["ns"] > 0, f"{name}: newton_schulz kernel not reached"
    for k in params:
        assert np.isfinite(np.asarray(upd[k])).all()


def test_dion_ns_for_qr_reached(monkeypatch):
    """dion fused='on' substitutes NS for QR (SUMO) — the kernel must fire."""
    calls = _spy(monkeypatch)
    params = _params()
    opt = get_optimizer("dion", lr=1e-2, rank=8, fused="on")
    st = opt.init(params)
    upd, _ = opt.update(_grads(0, params), st, params)
    assert calls["ns"] > 0, "dion: newton_schulz kernel not reached"
    for k in params:
        assert np.isfinite(np.asarray(upd[k])).all()


def test_ns_envelope_gate_falls_back_to_jnp(monkeypatch):
    """fused='on' must never send a factor whose short side exceeds the
    Pallas kernel's VMEM envelope (NS_PALLAS_MAX_RANK) through the kernel
    — its (r, r) scratch would not fit VMEM at production full-space
    shapes. Past the threshold dispatch degrades to the jnp iteration."""
    def boom(x, **kw):
        raise AssertionError(f"Pallas NS dispatched on {x.shape}")

    monkeypatch.setattr(fused_step.ops, "newton_schulz_op", boom)
    rng = np.random.default_rng(7)
    big = jnp.asarray(
        rng.standard_normal((fused_step.NS_PALLAS_MAX_RANK + 1,
                             fused_step.NS_PALLAS_MAX_RANK + 8)) * 0.1,
        jnp.float32)
    out = fused_step.fused_newton_schulz(big, steps=3, mode="on")
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(newton_schulz(big, steps=3)))


def test_fullspace_muon_big_leaf_avoids_pallas_ns(monkeypatch):
    """Full-space muon (rank=None) fused='on' on a production-sized leaf
    must take the jnp fallback, not the rank-sized kernel."""
    def boom(x, **kw):
        raise AssertionError(f"Pallas NS dispatched on {x.shape}")

    monkeypatch.setattr(fused_step.ops, "newton_schulz_op", boom)
    rng = np.random.default_rng(8)
    params = {"big": jnp.asarray(
        rng.standard_normal((fused_step.NS_PALLAS_MAX_RANK + 4, 560)) * 0.1,
        jnp.float32)}
    opt = get_optimizer("muon", lr=1e-2, fused="on")
    st = opt.init(params)
    upd, _ = opt.update(_grads(0, params), st, params)
    assert np.isfinite(np.asarray(upd["big"])).all()


# ---------------------------------------------------------------------------
# dion telemetry + ns_steps plumbing
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fused", ["off", "on"])
def test_dion_emits_subspace_stats(fused):
    """dion emits SubspaceStats like muon/trion: captured energy of
    span(P_t) from the R_t column norms, ranking-only fields at the -1
    sentinel."""
    params = _params()
    opt = get_optimizer("dion", lr=1e-2, rank=8, fused=fused)
    st = opt.init(params)
    with collect() as col:
        opt.update(_grads(0, params), st, params)
    tree = col.tree()
    assert tree, "dion emitted no SubspaceStats"
    for path, s in tree.items():
        ce = np.asarray(s.captured_energy)
        assert ((ce > 0) & (ce <= 1.0 + 1e-5)).all(), (path, ce)
        assert (np.asarray(s.topr_margin) == -1).all(), path
        assert (np.asarray(s.index_overlap) == -1).all(), path
        assert (np.asarray(s.ef_norm) > 0).all(), path
        ru = np.asarray(s.rank_utilization)
        assert ((ru > 0) & (ru <= 1.0 + 1e-5)).all(), (path, ru)


def test_dion_ns_steps_passthrough(monkeypatch):
    """ns_steps reaches the fused NS call through both public
    constructors (it used to be a DionRule-only field)."""
    seen = []
    orig = fused_step.fused_newton_schulz

    def ns_spy(b, *, steps, **kw):
        seen.append(steps)
        return orig(b, steps=steps, **kw)

    monkeypatch.setattr(fused_step, "fused_newton_schulz", ns_spy)
    params = _params()
    opt = get_optimizer("dion", lr=1e-2, rank=8, ns_steps=3, fused="on")
    st = opt.init(params)
    opt.update(_grads(0, params), st, params)
    assert seen and set(seen) == {3}, seen

    from repro.optim.api import get_transform
    from repro.optim.common import Context
    seen.clear()
    tr = get_transform("dion", lr=1e-2, rank=8, ns_steps=2, fused="on")
    st = tr.init(params)
    ctx = Context(step=jnp.zeros((), jnp.int32), bases={})
    tr.update(_grads(0, params), st, params, ctx)
    assert seen and set(seen) == {2}, seen


@pytest.mark.parametrize("name", ["muon", "trion", "dion"])
def test_ns_runs_on_rank_sized_blocks(monkeypatch, name):
    """The tentpole shape pin: every NS call in the subspace path sees a
    rank-sized block — min trailing dim == r, never the full (m, n)."""
    r = 8
    calls = _spy(monkeypatch)
    params = _params()
    opt = get_optimizer(name, lr=1e-2, rank=r, fused="on")
    st = opt.init(params)
    opt.update(_grads(0, params), st, params)
    assert calls["ns_shapes"], f"{name}: no NS calls recorded"
    for shape in calls["ns_shapes"]:
        assert min(shape[-2:]) == r, (
            f"{name}: NS ran on {shape}, not a rank-{r} block")
        assert max(shape[-2:]) < M * N, shape
