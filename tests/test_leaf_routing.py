"""Leaf-routing coverage: ``default_label_fn`` and custom routing through
``partition`` (satellite of the transform-chain redesign).

The default policy (paper practice): linear-layer matrices go low-rank;
embeddings / norms / biases / tiny or 1D leaves take the full-rank AdamW
fallback. Name hints win over shape.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import transform as tx
from repro.optim.common import FullAdamLeaf, default_label_fn, labelled_tree
from repro.optim.muon import MuonLeaf, MuonRule
from repro.optim.projected_adam import ProjAdamLeaf, ProjectedAdamRule


@pytest.mark.parametrize("path", [
    "embed/table", "unembed/w", "lm_head/w", "vocab_proj/w", "final_norm/w",
    "attn/scale", "mlp/bias", "pos_emb/w", "ssm/a_log", "ssm/dt_proj",
    "rwkv/decay", "conv1d/w",
])
def test_name_hints_force_full_path(path):
    """Every hinted name routes 'full' even for a big 2D matrix."""
    leaf = jnp.ones((256, 256))
    assert default_label_fn(path, leaf) == "full"


@pytest.mark.parametrize("shape", [(7,), (16,), (128,)])
def test_ndim_below_2_is_full(shape):
    assert default_label_fn("block/w", jnp.ones(shape)) == "full"


@pytest.mark.parametrize("shape,expect", [
    ((7, 128), "full"),      # min dim < 8: not worth projecting
    ((128, 7), "full"),
    ((8, 128), "lowrank"),   # boundary: min dim == 8 qualifies
    ((64, 64), "lowrank"),
])
def test_min_dim_threshold(shape, expect):
    assert default_label_fn("block/w", jnp.ones(shape)) == expect


def test_scan_stacked_leaves_route_lowrank():
    """(layers, m, n) and (layers, experts, m, n) stacked leaves are matrix
    leaves — routing looks at the trailing two dims."""
    assert default_label_fn("block/wq", jnp.ones((12, 64, 64))) == "lowrank"
    assert default_label_fn("moe/wi", jnp.ones((4, 8, 64, 32))) == "lowrank"
    # stacked but tiny trailing dims still fall back
    assert default_label_fn("block/w", jnp.ones((12, 4, 64))) == "full"


def test_labelled_tree_paths_join_nested_keys():
    params = {"block": {"attn": {"wq": jnp.ones((16, 16))},
                        "norm": jnp.ones((16,))},
              "embed": jnp.ones((32, 16))}
    labels = labelled_tree(params)
    assert labels["block"]["attn"]["wq"] == "lowrank"
    assert labels["block"]["norm"] == "full"
    assert labels["embed"] == "full"          # name hint beats 2D shape


def test_custom_label_fn_routes_two_rules_through_partition():
    """A user label_fn sends attention matrices to projected-Adam and MLP
    matrices to Muon — and each leaf's state proves where it landed."""
    params = {
        "attn": {"wq": jnp.ones((16, 32)), "wo": jnp.ones((32, 16))},
        "mlp": {"wi": jnp.ones((16, 48)), "wo": jnp.ones((48, 16))},
        "norm": jnp.ones((16,)),
    }

    def label_fn(path, leaf):
        if leaf.ndim < 2:
            return "full"
        return "attn" if path.startswith("attn") else "mlp"

    opt = tx.as_optimizer(tx.partition({
        "attn": tx.lowrank_project(ProjectedAdamRule(rank=4)),
        "mlp": tx.lowrank_project(MuonRule()),
        "full": tx.scale_by_adam(),
    }, label_fn))
    state = opt.init(params)

    for name in ("wq", "wo"):
        assert isinstance(state.leaves["attn"]["attn"][name], ProjAdamLeaf)
    for name in ("wi", "wo"):
        assert isinstance(state.leaves["mlp"]["mlp"][name], MuonLeaf)
    assert isinstance(state.leaves["full"]["norm"], FullAdamLeaf)

    g = {k: (jnp.full(v.shape, 0.1) if not isinstance(v, dict) else
             {kk: jnp.full(vv.shape, 0.1) for kk, vv in v.items()})
         for k, v in params.items()}
    upd, state2 = opt.update(g, state, params)
    assert all(np.isfinite(np.asarray(u)).all()
               for u in __import__("jax").tree.leaves(upd))
    # ProjAdam keeps low-rank moments; Muon keeps full-size momentum,
    # stored oriented (projected dim last) so ZeRO can row-shard it
    assert state2.leaves["attn"]["attn"]["wq"].m.shape == (32, 4)  # oriented
    assert state2.leaves["mlp"]["mlp"]["wi"].m.shape == (48, 16)
