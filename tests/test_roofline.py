"""Sanity tests for the trip-count-aware HLO cost model (roofline source)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline.hlo_cost import module_costs, parse_module
from repro.roofline.hlo_parse import collective_bytes


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_dot_flops_counted():
    n = 256
    sds = jax.ShapeDtypeStruct((n, n), jnp.float32)
    compiled = _compile(lambda a, b: a @ b, sds, sds)
    c = module_costs(compiled.as_text())
    expect = 2 * n**3
    assert 0.5 * expect <= c.flops <= 3 * expect, c.flops


def test_scan_multiplies_trip_count():
    """A scan with L iterations must cost ~L x the body (XLA's own
    cost_analysis counts the body once — the bug this model fixes)."""
    n, L = 128, 16
    sds = jax.ShapeDtypeStruct((n, n), jnp.float32)

    def fn(x):
        def body(c, _):
            return c @ x, None
        out, _ = jax.lax.scan(body, jnp.eye(n), None, length=L)
        return out

    compiled = _compile(fn, sds)
    c = module_costs(compiled.as_text())
    expect = 2 * n**3 * L
    assert 0.4 * expect <= c.flops <= 3 * expect, (c.flops, expect)
    from repro.parallel.compat import cost_analysis
    xla = cost_analysis(compiled).get("flops", 0.0)
    # document the discrepancy this model exists to fix
    assert xla < 0.5 * expect, "XLA now counts trips; revisit hlo_cost"


def test_bytes_reasonable_for_elementwise():
    n = 1 << 20
    sds = jax.ShapeDtypeStruct((n,), jnp.float32)
    compiled = _compile(lambda a, b: a + b, sds, sds)
    c = module_costs(compiled.as_text())
    expect = 3 * 4 * n          # 2 reads + 1 write
    assert 0.5 * expect <= c.bytes <= 3 * expect, c.bytes


def test_parse_module_handles_index_comments():
    txt = """HloModule m
ENTRY %main (a: f32[4]) -> (f32[4], f32[4]) {
  %a = f32[4]{0} parameter(0)
  %b = f32[4]{0} add(%a, %a)
  ROOT %t = (f32[4]{0}, /*index=1*/f32[4]{0}) tuple(%b, %a)
}
"""
    comps = parse_module(txt)
    assert "__entry__" in comps
    ops = [i.opcode for i in comps["__entry__"]]
    assert "add" in ops and "tuple" in ops


def test_collective_parser_shapes():
    txt = ("  %ag = f32[128,256]{1,0} all-gather(%x), dimensions={0}\n"
           "  %ar = (bf16[64]{0}, bf16[64]{0}) all-reduce(%a, %b)\n")
    stats = collective_bytes(txt)
    assert stats["all-gather"]["bytes"] == 128 * 256 * 4
    assert stats["all-reduce"]["bytes"] == 2 * 64 * 2
