"""Unit tests for the DCT basis and Makhoul's FFT algorithm."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dct import (
    dct2,
    dct2_matrix,
    dct3_matrix,
    dct_basis_np,
    makhoul_dct2,
)

SIZES = [4, 7, 16, 63, 128, 640, 1024]


@pytest.mark.parametrize("n", SIZES)
def test_dct3_matches_float64_oracle(n):
    q = np.asarray(dct3_matrix(n))
    np.testing.assert_allclose(q, dct_basis_np(n), atol=5e-7)


@pytest.mark.parametrize("n", SIZES)
def test_dct3_orthogonal(n):
    q = np.asarray(dct3_matrix(n), dtype=np.float64)
    np.testing.assert_allclose(q @ q.T, np.eye(n), atol=2e-5)
    np.testing.assert_allclose(q.T @ q, np.eye(n), atol=2e-5)


def test_dct2_is_transpose_of_dct3():
    np.testing.assert_array_equal(
        np.asarray(dct2_matrix(33)), np.asarray(dct3_matrix(33)).T
    )


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("rows", [1, 3])
def test_makhoul_equals_matmul(n, rows):
    rng = np.random.default_rng(n * 31 + rows)
    x = rng.standard_normal((rows, n)).astype(np.float32)
    s_mm = np.asarray(dct2(jnp.asarray(x), method="matmul"))
    s_fft = np.asarray(makhoul_dct2(jnp.asarray(x)))
    scale = np.abs(x).max() * np.sqrt(n)
    np.testing.assert_allclose(s_fft, s_mm, atol=2e-6 * scale)


def test_makhoul_energy_preserving():
    # orthonormal transform preserves Frobenius norm (Parseval)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((8, 256)).astype(np.float32)
    s = np.asarray(makhoul_dct2(jnp.asarray(x)))
    np.testing.assert_allclose(
        np.linalg.norm(s, axis=-1), np.linalg.norm(x, axis=-1), rtol=1e-5
    )


def test_dct_bf16_roundtrip_reasonable():
    # bf16 basis is what large archs store (DESIGN.md §7.3)
    n = 512
    q = np.asarray(dct3_matrix(n, dtype=jnp.bfloat16), dtype=np.float32)
    err = np.abs(q @ q.T - np.eye(n)).max()
    assert err < 0.1  # bf16 has ~3 decimal digits; basis still near-orthogonal


def test_order_limit_raises():
    with pytest.raises(ValueError):
        dct3_matrix(40_000)
