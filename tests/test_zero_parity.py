"""ZeRO-1 distributed-step parity (DESIGN.md §9).

Runs in a subprocess with 8 forced host devices (device count is locked at
first jax init). The partitioned step — rows of every eligible leaf's
moments/EF split over ('pod', 'data'), the fused select+project+update
running inside shard_map per shard, one (n,)-sized psum completing the
column statistic — must produce updates **bit-identical (fp32)** to the
replicated step: the row-block decomposition is exact, not approximate.

Covered: stacked / odd / transposed-orientation / ineligible leaves, the
"on" (Pallas interpret) / "fft" / "off" execution modes, q8 + fp32 EF and
discard residuals, keep-branch steps (T_u > 1), telemetry parity, the
ZeRO placement specs (per-device byte reduction), and sharded checkpoint
save -> restore onto a *different* topology (resharding) mid-run.
"""
import os
import subprocess
import sys
import textwrap

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import tempfile

    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_mesh
    from repro.optim.api import get_optimizer
    from repro.parallel import sharding as sh
    from repro.parallel.compat import set_mesh
    from repro.parallel.zero import ZeroConfig
    from repro.telemetry.stats import collect
    from repro.train.checkpoint import CheckpointManager

    mesh = make_mesh((2, 4), ("pod", "data"))     # N_dp = 8 over both axes
    zcfg = ZeroConfig(mode="1")
    rng = np.random.default_rng(0)

    params = {
        "w":    jnp.zeros((3, 64, 48), jnp.float32),  # scan-stacked
        "odd":  jnp.zeros((80, 33), jnp.float32),     # odd dims, rows first
        "wide": jnp.zeros((33, 80), jnp.float32),     # transposed orientation
        "bad":  jnp.zeros((36, 20), jnp.float32),     # 36 % 8 != 0 -> fallback
        "norm": jnp.zeros((64,), jnp.float32),        # full-rank Adam route
    }

    def grads_for(t):
        r = np.random.default_rng(100 + t)
        return {k: jnp.asarray(r.standard_normal(v.shape), jnp.float32)
                for k, v in params.items()}

    # ---- 1. bit-identical updates: fused and unfused, every leaf shape ----
    for fused, kw in [("off", {}), ("on", {}), ("fft", {}),
                      ("off", {"error_feedback": False}),
                      ("off", {"ef_dtype": "fp32"}),
                      ("off", {"update_interval": 2})]:
        ref = get_optimizer("dct_adamw", lr=0.01, rank=8, fused=fused, **kw)
        zo = get_optimizer("dct_adamw", lr=0.01, rank=8, fused=fused,
                           zero=zcfg, **kw)
        sr, sz = ref.init(params), zo.init(params)
        with set_mesh(mesh):
            for t in range(3):
                g = grads_for(t)
                ur, sr = jax.jit(ref.update)(g, sr, params)
                uz, sz = jax.jit(zo.update)(g, sz, params)
        for k in params:
            np.testing.assert_array_equal(
                np.asarray(ur[k]), np.asarray(uz[k]),
                err_msg=f"fused={fused} kw={kw} leaf={k}")

    # fira residual is excluded from sharding (its psum'd phi scaling
    # would feed the update arithmetic and break bit-exactness); its
    # leaves must fall back to the replicated path — parity exact
    ref = get_optimizer("fira", lr=0.01, rank=8, projector="dct")
    zo = get_optimizer("fira", lr=0.01, rank=8, projector="dct", zero=zcfg)
    sr, sz = ref.init(params), zo.init(params)
    with set_mesh(mesh):
        for t in range(2):
            g = grads_for(t)
            ur, sr = jax.jit(ref.update)(g, sr, params)
            uz, sz = jax.jit(zo.update)(g, sz, params)
    for k in params:
        np.testing.assert_array_equal(np.asarray(ur[k]), np.asarray(uz[k]),
                                      err_msg=f"fira leaf={k}")
    print("zero update parity OK")

    # ---- 2. telemetry parity (stats psum'd inside the shard_map) ----------
    ref = get_optimizer("dct_adamw", lr=0.01, rank=8)
    zo = get_optimizer("dct_adamw", lr=0.01, rank=8, zero=zcfg)
    g = grads_for(0)

    def run(opt, st):
        with collect() as col:
            u, st = opt.update(g, st, params)
        return u, st, col.tree()

    with set_mesh(mesh):
        _, _, tel_r = jax.jit(lambda s: run(ref, s))(ref.init(params))
        _, _, tel_z = jax.jit(lambda s: run(zo, s))(zo.init(params))
    assert set(tel_r) == set(tel_z) and tel_z, sorted(tel_z)
    for path in tel_r:
        for f in tel_r[path]._fields:
            np.testing.assert_allclose(
                np.asarray(getattr(tel_z[path], f)),
                np.asarray(getattr(tel_r[path], f)), atol=1e-5,
                err_msg=f"telemetry {path}.{f}")
    print("zero telemetry parity OK")

    # ---- 3. placement: ZeRO specs cut per-device state bytes --------------
    zo = get_optimizer("dct_adamw", lr=0.01, rank=8, zero=zcfg)
    with set_mesh(mesh):
        st = zo.init(params)
        p_specs = sh.params_specs(params, mesh)
        o_specs = sh.opt_state_specs(st, params, p_specs, zero=zcfg,
                                     mesh=mesh)
        st_sh = jax.device_put(st, sh.named_shardings(o_specs, mesh))
    pl = st_sh.leaves[0]["lowrank"]["w"]
    assert pl.m.sharding.spec == P(None, ("pod", "data"), None), pl.m.sharding
    assert pl.ef.q.sharding.spec == P(None, ("pod", "data"), None)
    assert pl.proj.sharding.spec == P()      # indices replicate

    def dev_bytes(tree, dev):
        return sum(s.data.nbytes for x in jax.tree.leaves(tree)
                   for s in x.addressable_shards if s.device == dev)

    d0 = jax.devices()[0]
    b_rep, b_sh = dev_bytes(st.leaves, d0), dev_bytes(st_sh.leaves, d0)
    assert b_sh < b_rep / 4, (b_sh, b_rep)   # idx/ineligible leaves replicate
    print(f"zero placement OK ({b_rep} -> {b_sh} bytes/device)")

    # ---- 4. sharded save -> restore on a DIFFERENT topology ---------------
    with set_mesh(mesh):
        for t in range(2):
            _, st_sh = jax.jit(zo.update, donate_argnums=1)(
                grads_for(t), st_sh, params)
        # replicated twin advanced identically (parity reference)
        st_rep = zo.init(params)
        for t in range(2):
            _, st_rep = jax.jit(zo.update)(grads_for(t), st_rep, params)

    cm = CheckpointManager(tempfile.mkdtemp(prefix="zck_"), keep=2)
    cm.save(2, st_sh)                        # gathered, mesh-agnostic
    mesh2 = make_mesh((4, 2), ("pod", "data"))
    with set_mesh(mesh2):
        target = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st_sh)
        o_specs2 = sh.opt_state_specs(target, params,
                                      sh.params_specs(params, mesh2),
                                      zero=zcfg, mesh=mesh2)
        st2 = cm.restore(2, target, shardings=sh.named_shardings(o_specs2,
                                                                 mesh2))
        assert (st2.leaves[0]["lowrank"]["w"].m.sharding.spec
                == P(None, ("pod", "data"), None))
        # one more step on the new topology must still match replicated
        u2, _ = jax.jit(zo.update)(grads_for(2), st2, params)
        ur, _ = jax.jit(zo.update)(grads_for(2), st_rep, params)
    for k in params:
        np.testing.assert_array_equal(np.asarray(u2[k]), np.asarray(ur[k]),
                                      err_msg=f"post-reshard leaf={k}")
    print("zero reshard restore OK")
""")


def test_zero_parity():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "zero update parity OK" in proc.stdout
    assert "zero telemetry parity OK" in proc.stdout
    assert "zero placement OK" in proc.stdout
    assert "zero reshard restore OK" in proc.stdout


# ---------------------------------------------------------------------------
# momentum-orthogonalization families (muon / trion / dion — DESIGN.md §14)
# ---------------------------------------------------------------------------
_SCRIPT_MOMENTUM = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import tempfile

    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_mesh
    from repro.optim.api import get_optimizer
    from repro.parallel import sharding as sh
    from repro.parallel.compat import set_mesh
    from repro.parallel.zero import ZeroConfig
    from repro.telemetry.stats import collect
    from repro.train.checkpoint import CheckpointManager

    mesh = make_mesh((2, 4), ("pod", "data"))     # N_dp = 8 over both axes
    zcfg = ZeroConfig(mode="1")

    params = {
        "w":    jnp.zeros((3, 64, 48), jnp.float32),  # scan-stacked
        "odd":  jnp.zeros((80, 33), jnp.float32),     # odd dims, rows first
        "wide": jnp.zeros((33, 80), jnp.float32),     # transposed orientation
        "bad":  jnp.zeros((36, 20), jnp.float32),     # 36 % 8 != 0 -> fallback
        "norm": jnp.zeros((64,), jnp.float32),        # full-rank Adam route
    }

    def grads_for(t):
        r = np.random.default_rng(100 + t)
        return {k: jnp.asarray(r.standard_normal(v.shape), jnp.float32)
                for k, v in params.items()}

    # ---- 1. bit-identical updates: every family x fused off/on ------------
    # muon both full-space (rank=None: NS on the all-gathered moment) and
    # subspace (NS on the rank-sized factor); 6 steps so momentum-driven
    # selection drift is exercised (trion's EF attracts boundary columns
    # toward ties — the gather-compute-slice scheme must stay exact)
    cases = [("muon", {}), ("muon", {"rank": 16}),
             ("trion", {"rank": 16}), ("dion", {"rank": 16})]
    for name, kw in cases:
        for fused in ("off", "on"):
            ref = get_optimizer(name, lr=0.01, fused=fused, **kw)
            zo = get_optimizer(name, lr=0.01, fused=fused, zero=zcfg, **kw)
            sr, sz = ref.init(params), zo.init(params)
            with set_mesh(mesh):
                for t in range(6):
                    g = grads_for(t)
                    ur, sr = jax.jit(ref.update)(g, sr, params)
                    uz, sz = jax.jit(zo.update)(g, sz, params)
                    for k in params:
                        np.testing.assert_array_equal(
                            np.asarray(ur[k]), np.asarray(uz[k]),
                            err_msg=f"{name} kw={kw} fused={fused} "
                                    f"step={t} leaf={k}")
    print("momentum zero update parity OK")

    # ---- 2. telemetry parity (subspace stats ride out of the shard_map) ---
    for name, kw in [("muon", {"rank": 16}), ("trion", {"rank": 16}),
                     ("dion", {"rank": 16})]:
        ref = get_optimizer(name, lr=0.01, **kw)
        zo = get_optimizer(name, lr=0.01, zero=zcfg, **kw)
        g = grads_for(0)

        def run(opt, st):
            with collect() as col:
                u, st = opt.update(g, st, params)
            return u, st, col.tree()

        with set_mesh(mesh):
            _, _, tel_r = jax.jit(lambda s: run(ref, s))(ref.init(params))
            _, _, tel_z = jax.jit(lambda s: run(zo, s))(zo.init(params))
        assert set(tel_r) == set(tel_z) and tel_z, (name, sorted(tel_z))
        for path in tel_r:
            for f in tel_r[path]._fields:
                np.testing.assert_allclose(
                    np.asarray(getattr(tel_z[path], f)),
                    np.asarray(getattr(tel_r[path], f)), atol=1e-5,
                    err_msg=f"{name} telemetry {path}.{f}")
    print("momentum zero telemetry parity OK")

    # ---- 3. placement: oriented momentum row-shards; dion q replicates ----
    for name, kw in [("muon", {"rank": 16}), ("trion", {"rank": 16}),
                     ("dion", {"rank": 16})]:
        zo = get_optimizer(name, lr=0.01, zero=zcfg, **kw)
        with set_mesh(mesh):
            st = zo.init(params)
            p_specs = sh.params_specs(params, mesh)
            o_specs = sh.opt_state_specs(st, params, p_specs, zero=zcfg,
                                         mesh=mesh)
            st_sh = jax.device_put(st, sh.named_shardings(o_specs, mesh))
        for leafname in ("w", "odd", "wide"):
            pl = st_sh.leaves[0]["lowrank"][leafname]
            lead = (None,) * (pl.m.ndim - 2)
            assert pl.m.sharding.spec == P(*lead, ("pod", "data"), None), (
                name, leafname, pl.m.sharding.spec)
            if hasattr(pl, "q"):
                assert pl.q.sharding.spec == P(), (name, leafname,
                                                   pl.q.sharding.spec)
        # ineligible leaf (36 % 8 != 0) mirrors the param placement
        bad = st_sh.leaves[0]["lowrank"]["bad"]
        assert bad.m.sharding.spec == p_specs["bad"], bad.m.sharding.spec

        def dev_bytes(tree, dev):
            return sum(s.data.nbytes for x in jax.tree.leaves(tree)
                       for s in x.addressable_shards if s.device == dev)

        d0 = jax.devices()[0]
        b_rep, b_sh = dev_bytes(st.leaves, d0), dev_bytes(st_sh.leaves, d0)
        assert b_sh < b_rep / 2, (name, b_sh, b_rep)
    print("momentum zero placement OK")

    # ---- 4. sharded save -> restore on a DIFFERENT topology ---------------
    zo = get_optimizer("trion", lr=0.01, rank=16, zero=zcfg)
    with set_mesh(mesh):
        st = zo.init(params)
        p_specs = sh.params_specs(params, mesh)
        o_specs = sh.opt_state_specs(st, params, p_specs, zero=zcfg,
                                     mesh=mesh)
        st_sh = jax.device_put(st, sh.named_shardings(o_specs, mesh))
        for t in range(2):
            _, st_sh = jax.jit(zo.update, donate_argnums=1)(
                grads_for(t), st_sh, params)
        st_rep = zo.init(params)
        for t in range(2):
            _, st_rep = jax.jit(zo.update)(grads_for(t), st_rep, params)

    cm = CheckpointManager(tempfile.mkdtemp(prefix="zckm_"), keep=2)
    cm.save(2, st_sh)                        # gathered, mesh-agnostic
    mesh2 = make_mesh((4, 2), ("pod", "data"))
    with set_mesh(mesh2):
        target = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st_sh)
        o_specs2 = sh.opt_state_specs(target, params,
                                      sh.params_specs(params, mesh2),
                                      zero=zcfg, mesh=mesh2)
        st2 = cm.restore(2, target, shardings=sh.named_shardings(o_specs2,
                                                                 mesh2))
        u2, _ = jax.jit(zo.update)(grads_for(2), st2, params)
        ur, _ = jax.jit(zo.update)(grads_for(2), st_rep, params)
    for k in params:
        np.testing.assert_array_equal(np.asarray(u2[k]), np.asarray(ur[k]),
                                      err_msg=f"post-reshard leaf={k}")
    print("momentum zero reshard restore OK")
""")


def test_zero_parity_momentum_families():
    """muon/trion/dion sharded updates bit-identical fp32 to replicated
    (fused off and on, stacked/odd/transposed leaves), telemetry parity,
    placement specs, and reshard-then-step (DESIGN.md §14)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT_MOMENTUM], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "momentum zero update parity OK" in proc.stdout
    assert "momentum zero telemetry parity OK" in proc.stdout
    assert "momentum zero placement OK" in proc.stdout
    assert "momentum zero reshard restore OK" in proc.stdout


def test_zero_shardable_gate():
    """Only index-based projectors shard, and the fira residual is
    excluded (its phi scaling would feed psum'd norms into the update)."""
    from repro.optim.projected_adam import ProjectedAdamRule

    assert ProjectedAdamRule(projector="dct").zero_shardable
    assert ProjectedAdamRule(projector="randperm",
                             needs_shared_basis=False).zero_shardable
    assert not ProjectedAdamRule(projector="svd",
                                 needs_shared_basis=False).zero_shardable
    assert not ProjectedAdamRule(projector="power",
                                 needs_shared_basis=False).zero_shardable
    assert not ProjectedAdamRule(projector="dct",
                                 residual="fira").zero_shardable

    # momentum-orthogonalization families (DESIGN.md §14): all shardable —
    # muon via psum'd ranking + rank-sized NS gather, trion/dion via full
    # gather-compute-slice
    from repro.optim.dion import DionRule
    from repro.optim.muon import MuonRule
    from repro.optim.trion import TrionRule

    assert MuonRule().zero_shardable
    assert MuonRule(rank=16).zero_shardable
    assert TrionRule(rank=16).zero_shardable
    assert DionRule(rank=16).zero_shardable


def test_zero_cli_gate():
    """--zero with a non-shardable optimizer must fail LOUDLY, not silently
    keep every leaf replicated (the PR-9 regression: the old gate only
    allowed dct_adamw and no-op'd everything else)."""
    import pytest

    from repro.launch.train import main

    base = ["--arch", "phi3-mini-3.8b", "--smoke", "--steps", "1",
            "--seq-len", "8", "--batch", "4", "--zero", "1"]
    # ldadamw's power-iteration projector state is not row-decomposable
    with pytest.raises(SystemExit, match="would silently stay replicated"):
        main(base + ["--optimizer", "ldadamw"])
    # galore/frugal only shard with an index-based predefined basis
    with pytest.raises(SystemExit, match="would silently stay replicated"):
        main(base + ["--optimizer", "galore"])
    # muon/trion/dion pass the shardable gate — proven by tripping the
    # NEXT gate (adaptive composition) instead of the shardable one
    for name in ("muon", "trion", "dion"):
        with pytest.raises(SystemExit, match="cannot be combined"):
            main(base + ["--optimizer", name, "--adaptive-rank"])


def test_zero_config_validation():
    from repro.parallel.zero import ZERO_OFF, ZeroConfig, parse_zero

    assert not ZERO_OFF.active
    assert parse_zero("1").active
    assert ZeroConfig(mode="1", axes=["data"]).axes == ("data",)
    try:
        ZeroConfig(mode="2")
    except ValueError as e:
        assert "zero mode" in str(e)
    else:
        raise AssertionError("mode '2' accepted")


def test_zero_inactive_without_mesh():
    """No mesh active -> resolve() is None and the optimizer runs the
    plain replicated path (same numbers as a zero=None build)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.optim.api import get_optimizer
    from repro.parallel.zero import ZeroConfig, resolve

    assert resolve(ZeroConfig(mode="1")) is None
    params = {"w": jnp.zeros((24, 16), jnp.float32)}
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((24, 16)),
                          jnp.float32)}
    a = get_optimizer("dct_adamw", lr=0.01, rank=4)
    b = get_optimizer("dct_adamw", lr=0.01, rank=4,
                      zero=ZeroConfig(mode="1"))
    ua, _ = jax.jit(a.update)(g, a.init(params), params)
    ub, _ = jax.jit(b.update)(g, b.init(params), params)
    np.testing.assert_array_equal(np.asarray(ua["w"]), np.asarray(ub["w"]))
