"""Parity suite for the fused projected-Adam execution layer (DESIGN.md §3).

The fused dispatch ("on" = Pallas kernels in interpret mode off-TPU, "fft" =
Makhoul host fast path) must match the seed jnp reference path ("off") to
fp32 tolerance across every projector kind x residual mode x stacked /
unstacked / odd-dimension shape, over multiple steps (so rotation, moments
and the quantized error-feedback buffer are all exercised through the state
feedback loop).

Also verifies — by spying on the kernel entry points, not by inspection —
that scan-stacked ``(layers, m, n)`` leaves actually dispatch to the batched
Pallas kernels instead of silently falling back.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fused_step
from repro.core.error_feedback import QuantizedBuffer, dequantize_q8
from repro.optim.common import Context
from repro.optim.projected_adam import ProjectedAdamRule

SHAPES = [
    (24, 40),       # plain 2D, projected dim last
    (3, 24, 40),    # scan-stacked layers
    (33, 17),       # odd, non-block-multiple dims (oriented: project dim 17)
]
KINDS = ["dct", "svd", "power", "random", "randperm"]
RESIDUALS = ["ef", "discard", "sign", "fira"]


def _run_steps(rule: ProjectedAdamRule, shape, n_steps=3, seed=0):
    """Drive rule.update through n_steps with synthetic gradients; return
    the per-step updates and the final state."""
    rng = np.random.default_rng(seed)
    state = rule.init(shape, jnp.float32)
    param = jnp.zeros(shape, jnp.float32)

    @functools.partial(jax.jit, static_argnames=())
    def step_fn(g, state, step):
        ctx = Context(step=step, bases={}, key=jax.random.PRNGKey(7))
        return rule.update(g, state, param, ctx)

    outs = []
    for t in range(1, n_steps + 1):
        g = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        d, state = step_fn(g, state, jnp.asarray(t, jnp.int32))
        outs.append(np.asarray(d))
    return outs, state


def _assert_step_parity(ref, got, label):
    # step 1 has no state feedback -> tight; later steps accumulate the
    # +-1-unit int8 EF rounding flips that a ~1e-6 S-matmul difference can
    # cause, so the tolerance widens with step index
    for t, (a, b) in enumerate(zip(ref, got)):
        tol = 3e-4 if t == 0 else 5e-3
        np.testing.assert_allclose(b, a, atol=tol, rtol=5e-3,
                                   err_msg=f"{label} step {t + 1}")


@pytest.mark.parametrize("shape", SHAPES, ids=["2d", "stacked", "odd"])
@pytest.mark.parametrize("residual", RESIDUALS)
@pytest.mark.parametrize("kind", KINDS)
def test_fused_kernel_matches_reference(kind, residual, shape):
    base = ProjectedAdamRule(rank=8, projector=kind, rotate=(kind == "dct"),
                             residual=residual, ef_dtype="q8", fused="off")
    ref, ref_state = _run_steps(base, shape)
    got, got_state = _run_steps(dataclasses.replace(base, fused="on"), shape)
    _assert_step_parity(ref, got, f"{kind}/{residual}")
    if residual == "ef":
        a, b = ref_state.ef, got_state.ef
        assert isinstance(b, QuantizedBuffer)
        np.testing.assert_allclose(
            np.asarray(dequantize_q8(b)), np.asarray(dequantize_q8(a)),
            atol=float(np.abs(np.asarray(a.scale)).max()) * 2 + 1e-5,
            err_msg=f"{kind}/{residual} EF buffer")


@pytest.mark.parametrize("shape", SHAPES, ids=["2d", "stacked", "odd"])
@pytest.mark.parametrize("residual", RESIDUALS)
def test_fused_fft_matches_reference(residual, shape):
    """The Makhoul host fast path — dct kind only (the fft transform IS the
    shared-basis projection)."""
    base = ProjectedAdamRule(rank=8, projector="dct", residual=residual,
                             ef_dtype="q8", fused="off")
    ref, _ = _run_steps(base, shape)
    got, _ = _run_steps(dataclasses.replace(base, fused="fft"), shape)
    _assert_step_parity(ref, got, f"fft/{residual}")


@pytest.mark.parametrize("ef_dtype", ["fp32", "q8"])
def test_fused_ef_dtypes(ef_dtype):
    base = ProjectedAdamRule(rank=8, projector="dct", residual="ef",
                             ef_dtype=ef_dtype, fused="off")
    ref, _ = _run_steps(base, (3, 24, 40))
    got, _ = _run_steps(dataclasses.replace(base, fused="on"), (3, 24, 40))
    _assert_step_parity(ref, got, f"ef_dtype={ef_dtype}")


def test_fused_update_interval_keep_branch():
    """T_u > 1 exercises the lax.cond keep branch (project with stale
    indices, identity rotation) on the fused path."""
    base = ProjectedAdamRule(rank=8, projector="dct", residual="ef",
                             ef_dtype="q8", update_interval=3, fused="off")
    ref, ref_state = _run_steps(base, (3, 24, 40), n_steps=5)
    got, got_state = _run_steps(dataclasses.replace(base, fused="on"),
                                (3, 24, 40), n_steps=5)
    _assert_step_parity(ref, got, "T_u=3")
    np.testing.assert_array_equal(np.asarray(ref_state.proj),
                                  np.asarray(got_state.proj))


def test_fused_exact_rotation_matmul():
    base = ProjectedAdamRule(rank=6, projector="dct", residual="discard",
                             exact_rotation_matmul=True, fused="off")
    ref, _ = _run_steps(base, (24, 40))
    got, _ = _run_steps(dataclasses.replace(base, fused="on"), (24, 40))
    _assert_step_parity(ref, got, "exact-rotation")


def test_fused_l1_ranking_norm():
    """Kernel path re-ranks from the resident S when the ranking norm is not
    the kernel's fused squared-l2."""
    base = ProjectedAdamRule(rank=8, projector="dct", residual="ef",
                             ranking_norm="l1", fused="off")
    ref, ref_state = _run_steps(base, (24, 40))
    got, got_state = _run_steps(dataclasses.replace(base, fused="on"),
                                (24, 40))
    _assert_step_parity(ref, got, "l1")
    np.testing.assert_array_equal(np.asarray(ref_state.proj),
                                  np.asarray(got_state.proj))


def test_stacked_leaf_dispatches_to_batched_kernels(monkeypatch):
    """A (layers, m, n) leaf must reach the batched kernel entry points with
    its leading axis intact — dispatch verified by spy, not inspection."""
    calls = {}

    def spy(name, orig):
        def wrapped(*args, **kw):
            calls.setdefault(name, []).append(
                tuple(a.ndim for a in args if hasattr(a, "ndim")))
            return orig(*args, **kw)
        return wrapped

    for name in ("dct_project_op", "colgather_matmul_dual_op",
                 "quantize_ef_op", "dequant_add_ef_op"):
        monkeypatch.setattr(fused_step.ops, name,
                            spy(name, getattr(fused_step.ops, name)))

    rule = ProjectedAdamRule(rank=8, projector="dct", residual="ef",
                             ef_dtype="q8", fused="on")
    _run_steps(rule, (3, 24, 40), n_steps=2)

    # g (3, m, n) hits the fused select+project kernel with its batch axis
    assert calls["dct_project_op"], "select+project kernel never dispatched"
    assert calls["dct_project_op"][0][0] == 3
    # both back-projections go through ONE dual-gather kernel call per step
    assert calls["colgather_matmul_dual_op"]
    assert calls["colgather_matmul_dual_op"][0][0] == 3
    # EF consumed and produced by the fused int8 kernels (no fp32 temp)
    assert calls["dequant_add_ef_op"] and calls["quantize_ef_op"]


def test_select_and_project_is_single_pass(monkeypatch):
    """The fused dct path performs exactly ONE G-sized matmul pass for
    select+project: one dct_project_op call, zero separate projection
    matmuls (idx + g_low both come out of it)."""
    n_calls = {"dct": 0}
    orig = fused_step.ops.dct_project_op

    def counting(*args, **kw):
        n_calls["dct"] += 1
        return orig(*args, **kw)

    monkeypatch.setattr(fused_step.ops, "dct_project_op", counting)
    gf = jnp.asarray(np.random.default_rng(0).standard_normal((24, 40)),
                     jnp.float32)
    from repro.core.dct import dct2_matrix
    q = dct2_matrix(40)
    idx, g_low = fused_step.select_and_project(gf, q, 8, mode="on")
    assert n_calls["dct"] == 1
    # and the extraction is exact: S[:, idx] == G @ Q[:, idx]
    from repro.core.selection import gather_columns
    qr = gather_columns(q, idx)
    np.testing.assert_allclose(np.asarray(g_low),
                               np.asarray(gf @ qr), atol=2e-5, rtol=1e-5)


def test_resolve_modes():
    assert fused_step.resolve("off") == "off"
    assert fused_step.resolve("on") == "on"
    assert fused_step.resolve("fft") == "fft"
    # auto degrades to the reference path off-TPU
    expected = "on" if fused_step.ops.ON_TPU else "off"
    assert fused_step.resolve("auto") == expected
    fused_step.set_default_fused_mode("fft")
    try:
        assert fused_step.resolve("auto") == "fft"
        assert fused_step.resolve("off") == "off"   # explicit beats default
    finally:
        fused_step.set_default_fused_mode("auto")
