"""Parity + behaviour suite for the composable gradient-transform API.

The load-bearing guarantee of the refactor: for every optimizer name in
``OPTIMIZERS``, the preset rebuilt as a chain produces updates and states
*identical* (fp32 bit-for-bit for the default ``fused="off"`` reference
path) to the pre-refactor monolithic harness, on stacked / odd /
transposed shapes. Also exercises ``partition`` with two different rules,
``inject_hyperparams`` changing lr mid-run without retracing (compile
count asserted), the primitive transforms, the chain runtime's shared-
basis collection, kernel dispatch *through* the chain, and the stable
path-hash PRNG keying.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import transform as tx
from repro.optim.adamw import adamw
from repro.optim.api import OPTIMIZERS, get_optimizer, get_transform
from repro.optim.common import (
    Context,
    FullAdamLeaf,
    HarnessState,
    MatrixRule,
    labelled_tree,
    make_matrix_optimizer,
    sched_value,
)
from repro.optim.muon import MuonRule
from repro.optim.dion import DionRule
from repro.optim.projected_adam import ProjectedAdamRule
from repro.optim.trion import TrionRule

# shapes: plain 2D, transposed (projected dim first), scan-stacked, odd
# non-block dims, and a 1D bias (full-rank fallback path)
def _params():
    rng = np.random.default_rng(0)

    def arr(*s):
        return jnp.asarray(rng.standard_normal(s), jnp.float32)

    return {
        "a": {"kernel": arr(24, 40)},
        "b": {"kernel": arr(40, 24)},          # transposed orientation
        "stacked": {"kernel": arr(3, 24, 40)},  # scan-stacked layers
        "odd": {"kernel": arr(33, 17)},
        "out_bias": jnp.zeros((7,)),
    }


def _grad_seq(params, n, seed=5):
    rng = np.random.default_rng(seed)
    return [jax.tree.map(
        lambda p: jnp.asarray(rng.standard_normal(p.shape), jnp.float32),
        params) for _ in range(n)]


# Legacy (pre-refactor) harness wiring for each preset: the rule + harness
# kwargs make_matrix_optimizer received before the chain rebuild.
def _legacy(name, lr):
    if name == "adamw":
        # old adamw == all-leaves-full harness with decoupled decay
        return make_matrix_optimizer(
            ProjectedAdamRule(), lr, weight_decay=0.01,
            label_fn=lambda path, leaf: "full")
    if name == "muon":
        return make_matrix_optimizer(MuonRule(), lr, weight_decay=0.01)
    if name == "dion":
        return make_matrix_optimizer(DionRule(rank=8), lr, weight_decay=0.01)
    if name == "trion":
        return make_matrix_optimizer(TrionRule(rank=8), lr, weight_decay=0.01)
    rules = {
        "dct_adamw": ProjectedAdamRule(rank=8, projector="dct",
                                       update_interval=1, rotate=True,
                                       residual="ef", ef_dtype="q8"),
        "ldadamw": ProjectedAdamRule(rank=8, projector="power",
                                     update_interval=1, rotate=True,
                                     residual="ef", ef_dtype="fp32",
                                     needs_shared_basis=False),
        "galore": ProjectedAdamRule(rank=8, projector="svd",
                                    update_interval=5, rotate=False,
                                    residual="discard",
                                    needs_shared_basis=False),
        "frugal": ProjectedAdamRule(rank=8, projector="svd",
                                    update_interval=5, rotate=False,
                                    residual="sign",
                                    needs_shared_basis=False),
        "fira": ProjectedAdamRule(rank=8, projector="svd",
                                  update_interval=5, rotate=False,
                                  residual="fira",
                                  needs_shared_basis=False),
    }
    rule = rules[name]
    return make_matrix_optimizer(rule, lr, weight_decay=0.01,
                                 b1=rule.b1, b2=rule.b2, eps=rule.eps)


PRESET_KW = {
    "adamw": {},
    "muon": {},
    "dion": {"rank": 8},
    "trion": {"rank": 8},
    "dct_adamw": {"rank": 8},
    "ldadamw": {"rank": 8},
    "galore": {"rank": 8, "update_interval": 5},
    "frugal": {"rank": 8, "update_interval": 5},
    "fira": {"rank": 8, "update_interval": 5},
}


def _merged_new_leaves(new_state, params, name):
    """Merge the chain preset's partition state back into a params-shaped
    tree of per-leaf states (the legacy HarnessState.leaves layout)."""
    if name == "adamw":
        return new_state.leaves[0]          # chain(scale_by_adam, lr, decay)
    part = new_state.leaves[0]              # chain(partition(...), lr, decay)
    labels = labelled_tree(params)
    return tx.merge_by_label(labels, part)


@pytest.mark.parametrize("name", sorted(OPTIMIZERS))
def test_chain_preset_matches_legacy_harness(name):
    """Bit-for-bit: updates AND states, 3 steps, default fused='off' path."""
    params = _params()
    lr = 2e-2
    legacy = _legacy(name, lr)
    new = get_optimizer(name, lr=lr, **PRESET_KW[name])
    sl, sn = legacy.init(params), new.init(params)

    # shared-basis store identical (collection moved into the chain runtime)
    assert set(sl.bases) == set(sn.bases)
    for k in sl.bases:
        np.testing.assert_array_equal(np.asarray(sl.bases[k]),
                                      np.asarray(sn.bases[k]))

    for t, g in enumerate(_grad_seq(params, 3)):
        ul, sl = legacy.update(g, sl, params)
        un, sn = new.update(g, sn, params)
        for (kp, a), (_, b) in zip(
                jax.tree_util.tree_flatten_with_path(ul)[0],
                jax.tree_util.tree_flatten_with_path(un)[0]):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"{name} step {t} update {kp}")

    assert int(sl.step) == int(sn.step)
    merged = _merged_new_leaves(sn, params, name)
    if name == "adamw":
        # legacy all-full harness leaves == chain scale_by_adam state
        ref_leaves = sl.leaves
    else:
        ref_leaves = sl.leaves
    assert (jax.tree_util.tree_structure(ref_leaves)
            == jax.tree_util.tree_structure(merged))
    for (kp, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(ref_leaves)[0],
            jax.tree_util.tree_flatten_with_path(merged)[0]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{name} state {kp}")


def test_chain_preset_matches_legacy_under_jit():
    """Same parity inside one jitted graph (the production path)."""
    params = _params()
    legacy = _legacy("dct_adamw", 1e-2)
    new = get_optimizer("dct_adamw", lr=1e-2, rank=8)
    sl, sn = legacy.init(params), new.init(params)
    for g in _grad_seq(params, 2):
        ul, sl = jax.jit(legacy.update)(g, sl, params)
        un, sn = jax.jit(new.update)(g, sn, params)
    for a, b in zip(jax.tree.leaves(ul), jax.tree.leaves(un)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# partition: arbitrary label sets, two different matrix rules
# ---------------------------------------------------------------------------
def test_partition_two_rules_mixed_policy():
    """dct-adamw on 'attn' matrices + muon on 'mlp' matrices + full Adam on
    the rest — the per-group policy the monolithic harness couldn't express."""
    params = {
        "attn": {"wq": jnp.ones((16, 32))},
        "mlp": {"wi": jnp.ones((16, 32))},
        "norm": jnp.ones((16,)),
    }

    def label_fn(path, leaf):
        if "attn" in path:
            return "attn"
        if "mlp" in path:
            return "mlp"
        return "full"

    t = tx.partition({
        "attn": get_transform("dct_adamw", lr=1e-2, rank=4, weight_decay=0.0),
        "mlp": get_transform("muon", lr=1e-3, weight_decay=0.0),
        "full": get_transform("adamw", lr=1e-4, weight_decay=0.0),
    }, label_fn)
    opt = tx.as_optimizer(t)
    state = opt.init(params)

    # per-label state landed under its own label, with the right leaf types
    from repro.optim.projected_adam import ProjAdamLeaf
    from repro.optim.muon import MuonLeaf
    assert isinstance(state.leaves["attn"][0]["attn"]["wq"], ProjAdamLeaf)
    assert isinstance(state.leaves["mlp"][0]["mlp"]["wi"], MuonLeaf)
    assert isinstance(state.leaves["full"][0]["norm"], FullAdamLeaf)
    # dct basis collected through partition masking: only attn's width
    assert set(state.bases) == {"16"}

    grads = jax.tree.map(lambda p: jnp.full(p.shape, 0.1), params)
    upd, state2 = jax.jit(opt.update)(grads, state, params)
    assert (jax.tree_util.tree_structure(state)
            == jax.tree_util.tree_structure(state2))
    # each group really got its own lr: |update| scales ~lr per group
    assert float(jnp.abs(upd["attn"]["wq"]).mean()) > \
        float(jnp.abs(upd["norm"]).mean())


def test_partition_unknown_label_raises_eagerly():
    with pytest.raises(ValueError, match="no transform"):
        tx.partition({"lowrank": tx.scale_by_adam()},
                     lambda path, leaf: "mystery").init(
            {"w": jnp.ones((8, 8))})


def test_partition_per_group_ranks():
    """Same rule family, different rank per group — AdaRankGrad-style."""
    params = {"big": jnp.ones((32, 64)), "small": jnp.ones((32, 64))}
    t = tx.partition({
        "hi": get_transform("dct_adamw", lr=1e-2, rank=16, weight_decay=0.0),
        "lo": get_transform("dct_adamw", lr=1e-2, rank=4, weight_decay=0.0),
    }, lambda path, leaf: "hi" if "big" in path else "lo")
    opt = tx.as_optimizer(t)
    state = opt.init(params)
    assert state.leaves["hi"][0]["big"].m.shape == (64, 16)   # oriented
    assert state.leaves["lo"][0]["small"].m.shape == (64, 4)
    grads = jax.tree.map(jnp.ones_like, params)
    upd, _ = opt.update(grads, state, params)
    assert all(np.isfinite(np.asarray(u)).all()
               for u in jax.tree.leaves(upd))


# ---------------------------------------------------------------------------
# inject_hyperparams: runtime lr change, no retrace
# ---------------------------------------------------------------------------
def test_inject_hyperparams_lr_change_no_retrace():
    params = {"w": jnp.ones((16, 32)), "b": jnp.zeros((8,))}
    grads = jax.tree.map(lambda p: jnp.full(p.shape, 0.5), params)

    from repro.optim.adamw import adamw_transform
    opt = tx.as_optimizer(tx.inject_hyperparams(adamw_transform)(
        lr=0.1, weight_decay=0.0))
    state = opt.init(params)
    assert set(state.leaves.hyperparams) >= {"lr", "weight_decay"}

    traces = {"n": 0}

    def counted(g, s, p):
        traces["n"] += 1
        return opt.update(g, s, p)

    step = jax.jit(counted)
    upd1, state = step(grads, state, params)

    # overwrite the lr state leaf — same structure, so NO retrace
    hp = dict(state.leaves.hyperparams)
    hp["lr"] = jnp.asarray(0.01, jnp.float32)
    state = state._replace(leaves=state.leaves._replace(hyperparams=hp))
    upd2, state = step(grads, state, params)

    assert traces["n"] == 1, "lr change retraced the step"
    # and the update actually shrank by ~10x (Adam direction is lr-invariant)
    r = float(jnp.abs(upd2["w"]).mean() / jnp.abs(upd1["w"]).mean())
    assert 0.05 < r < 0.2, r


def test_inject_hyperparams_matches_uninjected():
    """Injected floats must not change the math (up to the fp32 cast of the
    hyperparameters: the uninjected path folds python floats through float64
    intermediates like ``1.0 - b1`` before casting, the injected path holds
    fp32 state leaves — a last-ulp difference by construction)."""
    params = {"w": jnp.ones((16, 32))}
    grads = {"w": jnp.full((16, 32), 0.3)}
    from repro.optim.adamw import adamw_transform
    a = tx.as_optimizer(adamw_transform(1e-2, weight_decay=0.05))
    b = tx.as_optimizer(tx.inject_hyperparams(adamw_transform)(
        1e-2, weight_decay=0.05))
    sa, sb = a.init(params), b.init(params)
    for _ in range(2):
        ua, sa = a.update(grads, sa, params)
        ub, sb = b.update(grads, sb, params)
    np.testing.assert_allclose(np.asarray(ua["w"]), np.asarray(ub["w"]),
                               rtol=1e-5, atol=1e-7)


def test_inject_hyperparams_statics_stay_static():
    """ints/bools/strings are not lifted into state."""
    from repro.optim.projected_adam import dct_adamw_transform
    t = tx.inject_hyperparams(dct_adamw_transform)(
        lr=1e-2, rank=4, update_interval=2, ef_dtype="q8")
    state = t.init({"w": jnp.ones((16, 32))})
    assert "rank" not in state.hyperparams
    assert "update_interval" not in state.hyperparams
    assert "ef_dtype" not in state.hyperparams
    assert "lr" in state.hyperparams and "weight_decay" in state.hyperparams


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------
def test_clip_global_norm_transform():
    opt = tx.as_optimizer(tx.clip_global_norm(1.0))
    params = {"w": jnp.zeros((4, 4))}
    grads = {"w": jnp.full((4, 4), 10.0)}
    state = opt.init(params)
    upd, _ = opt.update(grads, state, params)
    norm = float(jnp.sqrt(jnp.sum(jnp.square(upd["w"]))))
    np.testing.assert_allclose(norm, 1.0, rtol=1e-6)
    # under the norm: passes through untouched
    upd2, _ = opt.update({"w": jnp.full((4, 4), 1e-3)}, state, params)
    np.testing.assert_allclose(np.asarray(upd2["w"]), 1e-3, rtol=1e-6)


def test_scale_by_schedule_uses_step():
    sched = lambda t: 0.1 * t.astype(jnp.float32)  # noqa: E731
    opt = tx.as_optimizer(tx.scale_by_schedule(sched))
    params = {"w": jnp.zeros((2, 2))}
    state = opt.init(params)
    g = {"w": jnp.ones((2, 2))}
    u1, state = opt.update(g, state, params)
    u2, state = opt.update(g, state, params)
    np.testing.assert_allclose(np.asarray(u1["w"]), 0.1, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(u2["w"]), 0.2, rtol=1e-6)


def test_add_decayed_weights_both_conventions():
    params = {"w": jnp.full((2, 2), 2.0)}
    g = {"w": jnp.zeros((2, 2))}
    # optax convention: u + wd*p, before lr scaling
    pre = tx.as_optimizer(tx.add_decayed_weights(0.5))
    u, _ = pre.update(g, pre.init(params), params)
    np.testing.assert_allclose(np.asarray(u["w"]), 1.0)
    # harness convention: u - lr_t*wd*p, after lr scaling
    post = tx.as_optimizer(tx.add_decayed_weights(0.5, schedule=0.1))
    u, _ = post.update(g, post.init(params), params)
    np.testing.assert_allclose(np.asarray(u["w"]), -0.1, rtol=1e-6)


def test_chain_threads_context_and_basis():
    """Any transform in the stack can request a shared basis via ctx."""
    seen = {}

    def probe_update(u, p, ctx):
        seen["step"] = ctx.step
        seen["basis"] = ctx.basis(12)
        return u

    probe = tx.GradientTransform(
        init=lambda p: tx.EmptyState(),
        update=lambda u, s, p, ctx: (probe_update(u, p, ctx), s),
        basis_sizes=lambda p: {12},
    )
    opt = tx.as_optimizer(tx.chain(probe, tx.scale_by_learning_rate(1.0)))
    params = {"w": jnp.ones((3, 3))}
    state = opt.init(params)
    assert set(state.bases) == {"12"}          # collected by the runtime
    opt.update({"w": jnp.ones((3, 3))}, state, params)
    assert seen["basis"].shape == (12, 12)
    assert int(seen["step"]) == 1


def test_onthefly_basis_mode_matches_stored():
    params = {"w": jnp.ones((24, 40))}
    g = {"w": jnp.full((24, 40), 0.1)}
    outs = []
    for mode in ("stored", "onthefly"):
        opt = get_optimizer("trion", lr=1e-2, rank=8, basis_mode=mode)
        state = opt.init(params)
        assert bool(state.bases) == (mode == "stored")
        u, _ = opt.update(g, state, params)
        outs.append(np.asarray(u["w"]))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-6)


# ---------------------------------------------------------------------------
# kernel dispatch THROUGH the chain (partition -> lowrank_project -> fused)
# ---------------------------------------------------------------------------
def test_fused_kernels_reached_through_partition(monkeypatch):
    """The fused Pallas path must still be dispatched when the rule runs
    inside partition/chain — the regression the CI bench also gates."""
    from repro.core import fused_step

    calls = {"n": 0}
    orig = fused_step.ops.dct_project_op

    def spy(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    monkeypatch.setattr(fused_step.ops, "dct_project_op", spy)
    params = {"w": jnp.ones((3, 24, 40))}
    grads = {"w": jnp.full((3, 24, 40), 0.1)}
    opt = get_optimizer("dct_adamw", lr=1e-2, rank=8, fused="on")
    state = opt.init(params)
    upd, _ = opt.update(grads, state, params)
    assert calls["n"] > 0, "fused kernel not reached through the chain"
    assert np.isfinite(np.asarray(upd["w"])).all()


# ---------------------------------------------------------------------------
# PRNG: stable path-hash keys (regression for enumeration-order reshuffle)
# ---------------------------------------------------------------------------
class _KeyProbeRule(MatrixRule):
    """Records the per-leaf ctx.key bits in its state."""

    def init(self, shape, dtype):
        return jnp.zeros((2,), jnp.uint32)

    def update(self, g, state, param, ctx):
        return jnp.zeros_like(g), jax.random.key_data(ctx.key).astype(
            jnp.uint32).reshape(-1)[:2]


def _leaf_keys(opt, params, merged_getter):
    g = jax.tree.map(jnp.ones_like, params)
    state = opt.init(params)
    _, state = opt.update(g, state, params)
    return merged_getter(state)


@pytest.mark.parametrize("build", ["chain", "legacy"])
def test_inserting_leaf_keeps_other_keys_stable(build):
    rule = _KeyProbeRule()
    base = {"a": {"kernel": jnp.ones((16, 16))},
            "z": {"kernel": jnp.ones((16, 16))}}
    grown = {"a": {"kernel": jnp.ones((16, 16))},
             "m": {"kernel": jnp.ones((16, 16))},   # inserted in the middle
             "z": {"kernel": jnp.ones((16, 16))}}

    if build == "chain":
        def make():
            return tx.as_optimizer(tx.partition(
                {"lowrank": tx.lowrank_project(rule),
                 "full": tx.scale_by_adam()}))

        def getter(state):
            return state.leaves["lowrank"]
    else:
        def make():
            return make_matrix_optimizer(rule, 1e-2)

        def getter(state):
            return state.leaves

    k_base = _leaf_keys(make(), base, getter)
    k_grown = _leaf_keys(make(), grown, getter)
    for name in ("a", "z"):
        np.testing.assert_array_equal(
            np.asarray(k_base[name]["kernel"]),
            np.asarray(k_grown[name]["kernel"]),
            err_msg=f"leaf {name}: key changed when a sibling was inserted")
    # and distinct leaves get distinct keys
    assert not np.array_equal(np.asarray(k_grown["a"]["kernel"]),
                              np.asarray(k_grown["m"]["kernel"]))


def test_path_hash_stable_constant():
    # crc32 is process-stable; pin one value so accidental hash-fn changes
    # (which would silently reshuffle all leaf randomness) are caught
    assert tx.path_hash("block/0/wq") == tx.path_hash("block/0/wq")
    assert tx.path_hash("block/0/wq") != tx.path_hash("block/1/wq")


# ---------------------------------------------------------------------------
# eager config validation
# ---------------------------------------------------------------------------
def test_projected_rule_validates_eagerly():
    with pytest.raises(ValueError, match="residual"):
        ProjectedAdamRule(residual="bogus")
    with pytest.raises(ValueError, match="ef_dtype"):
        ProjectedAdamRule(ef_dtype="fp16")
    with pytest.raises(ValueError, match="ranking_norm"):
        ProjectedAdamRule(ranking_norm="linf")
    with pytest.raises(ValueError, match="fused"):
        ProjectedAdamRule(fused="maybe")
    with pytest.raises(ValueError, match="projector"):
        ProjectedAdamRule(projector="qr")
    with pytest.raises(ValueError, match="rank"):
        ProjectedAdamRule(rank=0)
    with pytest.raises(ValueError, match="update_interval"):
        ProjectedAdamRule(update_interval=0)
    with pytest.raises(ValueError, match="dct_method"):
        TrionRule(dct_method="dft")


def test_get_optimizer_rejects_unknown_kwargs():
    with pytest.raises(TypeError, match="allowed"):
        get_optimizer("dct_adamw", lr=1e-2, rnak=8)
    with pytest.raises(TypeError, match="allowed"):
        get_optimizer("adamw", lr=1e-2, rank=8)
    with pytest.raises(KeyError, match="unknown optimizer"):
        get_optimizer("sgd", lr=1e-2)


def test_bad_preset_values_fail_at_construction():
    with pytest.raises(ValueError, match="fused"):
        get_optimizer("dct_adamw", lr=1e-2, fused="always")
    with pytest.raises(ValueError, match="basis_mode"):
        tx.as_optimizer(tx.scale_by_adam(), basis_mode="cached")
