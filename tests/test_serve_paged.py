"""Continuous-batching paged serving vs the dense engine.

The load-bearing claims (ISSUE acceptance):

  * a request decoded under continuous batching — admitted into a churning
    batch, neighbours coming and going — produces the same greedy fp32
    token stream as a solo run through the dense ``ServeEngine``;
  * a surviving slot's logits are *bit-for-bit* unchanged by admit/retire
    churn around it (per-slot computations are batch-row-independent and
    other sequences live in disjoint pool blocks);
  * backpressure queues requests, never drops them;
  * cancellation returns a sequence's blocks to the pool.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import SMOKES
from repro.models import transformer as T
from repro.serve import (PagedServeEngine, SamplingParams, ServeEngine,
                         Session)

FAMS = ["qwen2.5-32b", "phi3-mini-3.8b"]        # GQA and MHA


@pytest.fixture(scope="module")
def setup():
    out = {}
    for arch in FAMS:
        cfg = SMOKES[arch]
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        out[arch] = (cfg, params, ServeEngine(cfg, params, max_len=48))
    return out


def _dense_solo(dense, prompt, new, eos_id=None):
    out = dense.generate({"tokens": jnp.asarray(prompt[None], jnp.int32)},
                         max_new_tokens=new, eos_id=eos_id)
    return np.asarray(out)[0].tolist()


@pytest.mark.parametrize("arch", FAMS)
def test_churn_matches_dense_solo(setup, arch):
    """Greedy token streams under admit/retire churn == solo dense runs.
    Requests are submitted staggered so slots are reused mid-flight."""
    cfg, params, dense = setup[arch]
    rng = np.random.default_rng(0)
    eng = PagedServeEngine(cfg, params, block_size=4, num_blocks=32,
                           max_blocks_per_seq=6, num_slots=2,
                           max_prefill_len=16, prefill_chunk=8,
                           num_splits=2)
    sess = Session(eng, "churn")
    prompts = [rng.integers(0, cfg.vocab_size, (n,))
               for n in (9, 5, 11, 7)]
    budgets = [6, 3, 5, 4]

    h0 = sess.submit(prompts[0], max_new_tokens=budgets[0])
    h1 = sess.submit(prompts[1], max_new_tokens=budgets[1])
    eng.step(); eng.step()
    # h1 (budget 3) retires here-ish; admit two more mid-flight
    h2 = sess.submit(prompts[2], max_new_tokens=budgets[2])
    h3 = sess.submit(prompts[3], max_new_tokens=budgets[3])
    eng.run()

    for h, p, n in zip([h0, h1, h2, h3], prompts, budgets):
        assert h.tokens == _dense_solo(dense, p, n), h.request.request_id
        assert h.finish_reason == "length"
    s = eng.stats()
    assert s["running"] == 0 and s["free_blocks"] == 32


def test_surviving_slot_logits_bit_for_bit(setup):
    """Slot 0's per-step logits with neighbours churning around it are
    byte-identical to a solo run — not merely allclose."""
    cfg, params, _ = setup["qwen2.5-32b"]
    rng = np.random.default_rng(1)
    prompt_a = rng.integers(0, cfg.vocab_size, (9,))
    prompt_b = rng.integers(0, cfg.vocab_size, (6,))
    prompt_c = rng.integers(0, cfg.vocab_size, (4,))

    def run(churn):
        eng = PagedServeEngine(cfg, params, block_size=4, num_blocks=32,
                               max_blocks_per_seq=6, num_slots=2,
                               max_prefill_len=16, prefill_chunk=8)
        sess = Session(eng, "bits")
        ha = sess.submit(prompt_a, max_new_tokens=7)
        if churn:
            hb = sess.submit(prompt_b, max_new_tokens=2)
        rows = []
        while not ha.done:
            eng.step()
            if churn and hb.done and len(sess.handles) == 2:
                sess.submit(prompt_c, max_new_tokens=3)   # reuse b's slot
            rows.append(np.asarray(eng.last_logits[0]))
        return ha.tokens, np.stack(rows[:6])

    toks_solo, logits_solo = run(churn=False)
    toks_churn, logits_churn = run(churn=True)
    assert toks_solo == toks_churn
    assert logits_solo.tobytes() == logits_churn.tobytes()


def test_backpressure_queued_not_dropped(setup):
    """Pool fits ~2 sequences; 5 submitted. Admission stalls (FIFO), the
    queue drains as blocks free, every request finishes correctly."""
    cfg, params, dense = setup["qwen2.5-32b"]
    rng = np.random.default_rng(2)
    eng = PagedServeEngine(cfg, params, block_size=4, num_blocks=6,
                           max_blocks_per_seq=3, num_slots=3,
                           max_prefill_len=8, prefill_chunk=8)
    sess = Session(eng, "bp")
    prompts = [rng.integers(0, cfg.vocab_size, (5,)) for _ in range(5)]
    hs = [sess.submit(p, max_new_tokens=4) for p in prompts]

    eng.step()
    mid = eng.stats()
    assert mid["pending"] > 0                  # backpressure engaged...
    assert mid["running"] == 2                 # ...pool holds only two
    eng.run()
    for h, p in zip(hs, prompts):              # ...and nothing was dropped
        assert h.tokens == _dense_solo(dense, p, 4)


def test_cancellation_returns_blocks(setup):
    cfg, params, _ = setup["qwen2.5-32b"]
    rng = np.random.default_rng(3)
    eng = PagedServeEngine(cfg, params, block_size=4, num_blocks=16,
                           max_blocks_per_seq=4, num_slots=2,
                           max_prefill_len=8, prefill_chunk=8)
    sess = Session(eng, "cx")
    h1 = sess.submit(rng.integers(0, cfg.vocab_size, (6,)),
                     max_new_tokens=10)
    h2 = sess.submit(rng.integers(0, cfg.vocab_size, (6,)),
                     max_new_tokens=10)
    hq = sess.submit(rng.integers(0, cfg.vocab_size, (6,)),
                     max_new_tokens=10)        # queued (no free slot)
    eng.step()
    used = eng.cache.allocator.used_blocks
    assert used == 8 and len(eng.sched.pending) == 1
    hq.cancel()                                # queued: dropped, no blocks
    h1.cancel()                                # running: blocks come back
    eng.step()
    assert hq.finish_reason == "cancelled" and hq.tokens == []
    assert h1.finish_reason == "cancelled"
    assert eng.cache.allocator.used_blocks == 4
    eng.run()
    assert h2.finish_reason == "length" and len(h2.tokens) == 10


def test_per_request_eos_and_sampling_lanes(setup):
    """eos is per-sequence; sampled streams depend only on (seed, pos),
    not on slot index or batch composition."""
    cfg, params, dense = setup["qwen2.5-32b"]
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, (7,))
    eng = PagedServeEngine(cfg, params, block_size=4, num_blocks=32,
                           max_blocks_per_seq=5, num_slots=3,
                           max_prefill_len=8, prefill_chunk=8)
    sess = Session(eng, "mix")

    greedy = _dense_solo(dense, prompt, 6)
    eos = greedy[2]                            # forces an early stop
    stop = greedy.index(eos) + 1               # (robust to repeats)
    sp = SamplingParams(temperature=0.9, top_k=40, top_p=0.95, seed=11)

    h_eos = sess.submit(prompt, max_new_tokens=6, eos_id=eos)
    h_smp = sess.submit(prompt, max_new_tokens=6, sampling=sp)
    h_grd = sess.submit(prompt, max_new_tokens=6)
    eng.run()
    assert h_eos.tokens == greedy[:stop] and h_eos.finish_reason == "eos"
    assert h_grd.tokens == greedy
    assert len(h_smp.tokens) == 6

    # same sampled request resubmitted alone: identical stream
    h_again = sess.submit(prompt, max_new_tokens=6, sampling=sp)
    eng.run()
    assert h_again.tokens == h_smp.tokens

    # dense engine matches the paged greedy stream (shared eos semantics)
    assert _dense_solo(dense, prompt, 6, eos_id=eos) == greedy[:stop]


def test_streaming_and_callbacks(setup):
    cfg, params, dense = setup["qwen2.5-32b"]
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, (6,))
    eng = PagedServeEngine(cfg, params, block_size=4, num_blocks=16,
                           max_blocks_per_seq=4, num_slots=2,
                           max_prefill_len=8, prefill_chunk=8)
    seen = []
    sess = Session(eng, "st")
    h = sess.submit(prompt, max_new_tokens=5,
                    on_token=lambda req, t: seen.append(t))
    streamed = list(h.stream())
    want = _dense_solo(dense, prompt, 5)
    assert streamed == want == seen == h.tokens
